//! PJRT executors for the AOT model steps.
//!
//! One compiled executable per model variant (`evolvegcn_step`,
//! `gcrn_m2_step`, `gcn_forward`), loaded from HLO text — the interchange
//! format this environment's xla_extension accepts (see
//! `python/compile/aot.py`).  Argument order mirrors the manifest.
//!
//! All three model variants run through one generic [`StepRunner`] that
//! owns persistent staging state: the padded graph buffers, the padded
//! feature buffer, the argument-literal vector (weight literals built
//! once at construction — the paper's one-time weight load — and
//! per-step slots overwritten in place), and `&mut` out-buffers instead
//! of freshly returned `Vec`s.  On the steady-state path the only
//! remaining Rust-side allocation is the transient copy `to_vec`
//! performs inside the XLA readback bridge; the staging side is
//! allocation-free (asserted by `tests/alloc_hotpath.rs`).
//!
//! Staged steps (`run_*_staged`) consume a pipeline [`StagingSlot`]
//! filled on a producer thread.  The slot also carries the snapshot's
//! destination-major CSR (`slot.csr`); PJRT execution ignores it — the
//! HLO gathers over the padded COO arrays — but host-side mirror
//! cross-checks and CPU baselines feed it to `numerics::spmm` so they
//! never re-derive the adjacency on the consumer thread.

use crate::error::{Error, Result};
use crate::graph::Snapshot;
use crate::models::{EvolveGcnParams, GcrnM1Params, GcrnM2Params};
use crate::runtime::manifest::Manifest;
use crate::runtime::pad::{pad_rows, PaddedGraph, StagingSlot};

/// A compiled HLO step function on the PJRT CPU client.
pub struct StepExecutable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl StepExecutable {
    /// Load `<dir>/<name>.hlo.txt` and compile it.
    pub fn load(client: &xla::PjRtClient, dir: &str, name: &str) -> Result<StepExecutable> {
        let path = format!("{dir}/{name}.hlo.txt");
        if !std::path::Path::new(&path).exists() {
            return Err(Error::Artifact(format!(
                "{path} not found (run `make artifacts`)"
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(StepExecutable { name: name.to_string(), exe })
    }

    /// Execute with the given literals; returns the flattened output
    /// tuple (lowered with return_tuple=True).
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(args)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

/// f32 literal from a slice with a shape.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        dims,
        bytes,
    )?)
}

/// i32 literal from a slice with a shape.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        dims,
        bytes,
    )?)
}

/// Read an f32 literal into a reusable host buffer.  The caller's `Vec`
/// keeps its allocation across steps; the transient copy lives inside
/// the XLA readback bridge.
fn read_into(lit: &xla::Literal, out: &mut Vec<f32>) -> Result<()> {
    let v = lit.to_vec::<f32>()?;
    out.clear();
    out.extend_from_slice(&v);
    Ok(())
}

/// Which compiled step artifact a [`StepRunner`] drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepKind {
    /// `evolvegcn_step`: weights-evolved; inputs w1/w2, outputs (y, w1, w2).
    EvolveGcn,
    /// `gcrn_m1_step`: stacked; inputs h/c, outputs (h, c).
    GcrnM1,
    /// `gcrn_m2_step`: integrated; inputs h/c, outputs (h, c).
    GcrnM2,
}

impl StepKind {
    pub fn artifact_name(self) -> &'static str {
        match self {
            StepKind::EvolveGcn => "evolvegcn_step",
            StepKind::GcrnM1 => "gcrn_m1_step",
            StepKind::GcrnM2 => "gcrn_m2_step",
        }
    }
}

/// Overwrite the five graph/feature argument slots in place from padded
/// staging buffers (either the runner's own or a pipeline
/// [`StagingSlot`]'s).
fn set_graph_args(
    args: &mut [xla::Literal],
    m: &Manifest,
    g: &PaddedGraph,
    x: &[f32],
) -> Result<()> {
    if g.max_edges != m.max_edges || g.max_nodes != m.max_nodes
        || x.len() != m.max_nodes * m.in_dim
    {
        return Err(Error::Artifact(format!(
            "staging buffers mismatch manifest: edges {}/{}, nodes {}/{}, x {}/{}",
            g.max_edges,
            m.max_edges,
            g.max_nodes,
            m.max_nodes,
            x.len(),
            m.max_nodes * m.in_dim
        )));
    }
    args[0] = lit_i32(&g.src, &[m.max_edges])?;
    args[1] = lit_i32(&g.dst, &[m.max_edges])?;
    args[2] = lit_f32(&g.coef, &[m.max_edges])?;
    args[3] = lit_f32(&g.selfcoef, &[m.max_nodes])?;
    args[4] = lit_f32(x, &[m.max_nodes, m.in_dim])?;
    Ok(())
}

/// Generic step-execution core shared by all model variants.
///
/// Argument layout (mirrors every step artifact's signature):
/// slots `0..5` are graph + features, slots `5..7` are the evolving
/// state (w1/w2 for EvolveGCN, h/c for the GCRN variants), and the tail
/// holds the fixed weight literals built once at construction.  Per-step
/// slots are overwritten in place, so the argument vector itself is
/// never reallocated.
pub struct StepRunner {
    kind: StepKind,
    step: StepExecutable,
    manifest: Manifest,
    /// `[graph..5, state 5..7, fixed weights 7..]`; leading slots
    /// rewritten each step.
    args: Vec<xla::Literal>,
    /// Internal staging for the unstaged (`run_*` from a raw snapshot)
    /// path.
    padded: PaddedGraph,
    x_buf: Vec<f32>,
}

impl StepRunner {
    /// Compile `kind`'s artifact and pre-build the argument vector.
    /// `weight_lits` are the model's fixed parameters in artifact order.
    pub fn new(
        client: &xla::PjRtClient,
        dir: &str,
        kind: StepKind,
        weight_lits: Vec<xla::Literal>,
    ) -> Result<StepRunner> {
        let manifest = Manifest::load(dir)?;
        let step = StepExecutable::load(client, dir, kind.artifact_name())?;
        let m = &manifest;
        let padded = PaddedGraph::new(m);
        let x_buf = vec![0.0f32; m.max_nodes * m.in_dim];
        let zero_edges = vec![0i32; m.max_edges];
        let zero_coef = vec![0.0f32; m.max_edges];
        let zero_nodes = vec![0.0f32; m.max_nodes];
        let (d5, d6) = match kind {
            StepKind::EvolveGcn => (
                [m.in_dim, m.hidden_dim],
                [m.hidden_dim, m.out_dim],
            ),
            StepKind::GcrnM1 | StepKind::GcrnM2 => (
                [m.max_nodes, m.hidden_dim],
                [m.max_nodes, m.hidden_dim],
            ),
        };
        let z5 = vec![0.0f32; d5[0] * d5[1]];
        let z6 = vec![0.0f32; d6[0] * d6[1]];
        let mut args = Vec::with_capacity(7 + weight_lits.len());
        args.push(lit_i32(&zero_edges, &[m.max_edges])?);
        args.push(lit_i32(&zero_edges, &[m.max_edges])?);
        args.push(lit_f32(&zero_coef, &[m.max_edges])?);
        args.push(lit_f32(&zero_nodes, &[m.max_nodes])?);
        args.push(lit_f32(&x_buf, &[m.max_nodes, m.in_dim])?);
        args.push(lit_f32(&z5, &d5)?);
        args.push(lit_f32(&z6, &d6)?);
        args.extend(weight_lits);
        Ok(StepRunner { kind, step, manifest, args, padded, x_buf })
    }

    pub fn kind(&self) -> StepKind {
        self.kind
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Recurrent step (GCRN variants) from a raw snapshot: pads
    /// internally, then executes.  `h`/`c` are padded
    /// `[max_nodes × hidden_dim]` buffers, overwritten with the new
    /// state.
    pub fn run_recurrent(
        &mut self,
        snap: &Snapshot,
        x: &[f32],
        h: &mut Vec<f32>,
        c: &mut Vec<f32>,
    ) -> Result<()> {
        self.padded.fill(snap)?;
        pad_rows(
            x,
            snap.num_nodes(),
            self.manifest.in_dim,
            self.manifest.max_nodes,
            &mut self.x_buf,
        );
        set_graph_args(&mut self.args, &self.manifest, &self.padded, &self.x_buf)?;
        self.finish_recurrent(h, c)
    }

    /// Recurrent step from a pre-staged slot (graph + features already
    /// padded on the pipeline's stage thread).
    pub fn run_recurrent_staged(
        &mut self,
        slot: &StagingSlot,
        h: &mut Vec<f32>,
        c: &mut Vec<f32>,
    ) -> Result<()> {
        set_graph_args(&mut self.args, &self.manifest, &slot.graph, &slot.x)?;
        self.finish_recurrent(h, c)
    }

    /// Weights-evolved step (EvolveGCN) from a raw snapshot.  `w1`/`w2`
    /// are the evolving weights, updated in place; `out` receives the
    /// first `num_nodes × out_dim` embeddings.
    pub fn run_evolve(
        &mut self,
        snap: &Snapshot,
        x: &[f32],
        w1: &mut Vec<f32>,
        w2: &mut Vec<f32>,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let n = snap.num_nodes();
        self.padded.fill(snap)?;
        pad_rows(
            x,
            n,
            self.manifest.in_dim,
            self.manifest.max_nodes,
            &mut self.x_buf,
        );
        set_graph_args(&mut self.args, &self.manifest, &self.padded, &self.x_buf)?;
        self.finish_evolve(w1, w2, out, n)
    }

    /// Weights-evolved step from a pre-staged slot.
    pub fn run_evolve_staged(
        &mut self,
        slot: &StagingSlot,
        w1: &mut Vec<f32>,
        w2: &mut Vec<f32>,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        set_graph_args(&mut self.args, &self.manifest, &slot.graph, &slot.x)?;
        self.finish_evolve(w1, w2, out, slot.graph.num_nodes)
    }

    fn finish_recurrent(&mut self, h: &mut Vec<f32>, c: &mut Vec<f32>) -> Result<()> {
        if self.kind == StepKind::EvolveGcn {
            return Err(Error::Artifact(
                "recurrent step requested on an EvolveGCN runner".into(),
            ));
        }
        let (mn, hd) = (self.manifest.max_nodes, self.manifest.hidden_dim);
        if h.len() != mn * hd || c.len() != mn * hd {
            return Err(Error::Artifact(format!(
                "state buffers must be padded to {mn}×{hd} (got h={}, c={})",
                h.len(),
                c.len()
            )));
        }
        self.args[5] = lit_f32(h, &[mn, hd])?;
        self.args[6] = lit_f32(c, &[mn, hd])?;
        let outs = self.execute()?;
        if outs.len() != 2 {
            return Err(Error::Artifact(format!(
                "{} returned {} outputs, want 2",
                self.kind.artifact_name(),
                outs.len()
            )));
        }
        read_into(&outs[0], h)?;
        read_into(&outs[1], c)?;
        Ok(())
    }

    fn finish_evolve(
        &mut self,
        w1: &mut Vec<f32>,
        w2: &mut Vec<f32>,
        out: &mut Vec<f32>,
        n_valid: usize,
    ) -> Result<()> {
        if self.kind != StepKind::EvolveGcn {
            return Err(Error::Artifact(
                "evolve step requested on a recurrent runner".into(),
            ));
        }
        let (ind, hd, od) = (
            self.manifest.in_dim,
            self.manifest.hidden_dim,
            self.manifest.out_dim,
        );
        if w1.len() != ind * hd || w2.len() != hd * od {
            return Err(Error::Artifact(format!(
                "weight buffers must be {ind}×{hd} and {hd}×{od} (got {}, {})",
                w1.len(),
                w2.len()
            )));
        }
        self.args[5] = lit_f32(w1, &[ind, hd])?;
        self.args[6] = lit_f32(w2, &[hd, od])?;
        let outs = self.execute()?;
        if outs.len() != 3 {
            return Err(Error::Artifact(format!(
                "{} returned {} outputs, want 3",
                self.kind.artifact_name(),
                outs.len()
            )));
        }
        read_into(&outs[0], out)?;
        read_into(&outs[1], w1)?;
        read_into(&outs[2], w2)?;
        out.truncate(n_valid * od);
        Ok(())
    }

    fn execute(&self) -> Result<Vec<xla::Literal>> {
        let result = self.step.exe.execute::<xla::Literal>(&self.args)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

/// EvolveGCN runtime: a [`StepRunner`] plus the evolving-weight host
/// copies (the GRU parameter literals are loaded once — the paper's
/// one-time weight load).
pub struct EvolveGcnExecutor {
    runner: StepRunner,
    /// Evolving weights, row-major host copies, updated in place.
    pub w1: Vec<f32>,
    pub w2: Vec<f32>,
}

impl EvolveGcnExecutor {
    pub fn new(
        client: &xla::PjRtClient,
        dir: &str,
        params: &EvolveGcnParams,
    ) -> Result<EvolveGcnExecutor> {
        let d = params.dims;
        let mut gru_lits = Vec::with_capacity(18);
        for (gp, rows, cols) in [
            (&params.gru1, d.in_dim, d.hidden_dim),
            (&params.gru2, d.hidden_dim, d.out_dim),
        ] {
            for (i, m) in gp.mats.iter().enumerate() {
                let is_bias = i % 3 == 2;
                let shape = if is_bias { [rows, cols] } else { [rows, rows] };
                gru_lits.push(lit_f32(m, &shape)?);
            }
        }
        let runner = StepRunner::new(client, dir, StepKind::EvolveGcn, gru_lits)?;
        Ok(EvolveGcnExecutor {
            runner,
            w1: params.w1.clone(),
            w2: params.w2.clone(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        self.runner.manifest()
    }

    /// One snapshot step into a reused output buffer (the steady-state
    /// hot path): updates the evolving weights in place and writes the
    /// `[num_nodes × out_dim]` embeddings into `out`.
    pub fn run_step_into(&mut self, snap: &Snapshot, x: &[f32], out: &mut Vec<f32>) -> Result<()> {
        self.runner.run_evolve(snap, x, &mut self.w1, &mut self.w2, out)
    }

    /// Staged variant: graph + features already padded into `slot` by
    /// the pipeline's stage thread.
    pub fn run_step_staged(&mut self, slot: &StagingSlot, out: &mut Vec<f32>) -> Result<()> {
        self.runner.run_evolve_staged(slot, &mut self.w1, &mut self.w2, out)
    }

    /// Convenience wrapper returning a fresh `Vec` (allocates; use
    /// [`Self::run_step_into`] on the hot path).
    pub fn run_step(&mut self, snap: &Snapshot, x: &[f32]) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.run_step_into(snap, x, &mut out)?;
        Ok(out)
    }
}

/// GCRN-M1 (stacked DGNN) runtime.  Demonstrates the framework's
/// genericity — same [`StepRunner`] core, a different per-snapshot step
/// artifact and weight literals.
pub struct GcrnM1Executor {
    runner: StepRunner,
}

impl GcrnM1Executor {
    pub fn new(client: &xla::PjRtClient, dir: &str, params: &GcrnM1Params) -> Result<GcrnM1Executor> {
        let d = params.dims;
        let w_lits = vec![
            lit_f32(&params.w1, &[d.in_dim, d.hidden_dim])?,
            lit_f32(&params.w2, &[d.hidden_dim, d.out_dim])?,
            lit_f32(&params.wx, &[d.out_dim, 4 * d.hidden_dim])?,
            lit_f32(&params.wh, &[d.hidden_dim, 4 * d.hidden_dim])?,
            lit_f32(&params.b, &[4 * d.hidden_dim])?,
        ];
        Ok(GcrnM1Executor {
            runner: StepRunner::new(client, dir, StepKind::GcrnM1, w_lits)?,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        self.runner.manifest()
    }

    /// One snapshot step; `h`/`c` are padded state buffers, overwritten.
    pub fn run_step(
        &mut self,
        snap: &Snapshot,
        x: &[f32],
        h: &mut Vec<f32>,
        c: &mut Vec<f32>,
    ) -> Result<()> {
        self.runner.run_recurrent(snap, x, h, c)
    }

    /// Staged variant (graph + features pre-padded in `slot`).
    pub fn run_step_staged(
        &mut self,
        slot: &StagingSlot,
        h: &mut Vec<f32>,
        c: &mut Vec<f32>,
    ) -> Result<()> {
        self.runner.run_recurrent_staged(slot, h, c)
    }
}

/// GCRN-M2 runtime; recurrent state lives in
/// `coordinator::NodeStateStore` / `coordinator::ResidentState`.
pub struct GcrnExecutor {
    runner: StepRunner,
}

impl GcrnExecutor {
    pub fn new(client: &xla::PjRtClient, dir: &str, params: &GcrnM2Params) -> Result<GcrnExecutor> {
        let d = params.dims;
        let w_lits = vec![
            lit_f32(&params.wx, &[d.in_dim, 4 * d.hidden_dim])?,
            lit_f32(&params.wh, &[d.hidden_dim, 4 * d.hidden_dim])?,
            lit_f32(&params.b, &[4 * d.hidden_dim])?,
        ];
        Ok(GcrnExecutor {
            runner: StepRunner::new(client, dir, StepKind::GcrnM2, w_lits)?,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        self.runner.manifest()
    }

    /// Run one snapshot step.  `h`/`c` are padded `[max_nodes × hidden]`
    /// buffers (gathered by the caller from DRAM state, or resident via
    /// `coordinator::ResidentState`); they are overwritten with the new
    /// state.  The new H *is* the output embedding for integrated DGNNs.
    pub fn run_step(
        &mut self,
        snap: &Snapshot,
        x: &[f32],
        h: &mut Vec<f32>,
        c: &mut Vec<f32>,
    ) -> Result<()> {
        self.runner.run_recurrent(snap, x, h, c)
    }

    /// Staged variant (graph + features pre-padded in `slot`).
    pub fn run_step_staged(
        &mut self,
        slot: &StagingSlot,
        h: &mut Vec<f32>,
        c: &mut Vec<f32>,
    ) -> Result<()> {
        self.runner.run_recurrent_staged(slot, h, c)
    }
}
