//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them on
//! the request path.
//!
//! `make artifacts` (Python, build-time only) lowers the L2 model steps
//! to HLO *text*; this module parses the [`manifest`], [`pad`]s each
//! snapshot to the fixed AOT shapes, and [`executor`] compiles + runs the
//! computations on the PJRT CPU client (`xla` crate).  No Python is ever
//! imported at runtime.

pub mod executor;
pub mod manifest;
pub mod pad;

pub use executor::{EvolveGcnExecutor, GcrnExecutor, GcrnM1Executor, StepExecutable, StepKind, StepRunner};
pub use manifest::Manifest;
pub use pad::{PaddedGraph, StagingSlot};
