//! Snapshot padding to the fixed AOT shapes.
//!
//! The padding contract (shared with `python/compile/model.py`):
//! * padded edges: `src = dst = 0`, `coef = 0.0` → contribute nothing;
//! * padded node rows: `selfcoef = 0.0`; feature/state rows zero;
//! * consumers read back only the first `num_nodes` rows.
//!
//! Buffers are reusable across snapshots (the hot path never
//! reallocates — asserted by `rust/tests/alloc_hotpath.rs`).

use crate::error::{Error, Result};
use crate::fpga::incremental::{DeltaPlan, DeltaStats};
use crate::graph::{CsrRebuild, EdgeDelta, Snapshot, SnapshotCsr, DELTA_CHURN_MAX};
use crate::runtime::manifest::Manifest;
use std::collections::HashMap;

/// Reinterpret a `&[u32]` of local node ids as `&[i32]` (same layout;
/// ids are bounded by the node budget, far below 2³¹).
fn ids_as_i32(v: &[u32]) -> &[i32] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const i32, v.len()) }
}

/// Reusable padded buffers for one snapshot's graph arrays.
///
/// Between fills the buffers must be treated as read-only: `fill` tracks
/// a high-water mark so only the previously-dirty tail is re-zeroed, and
/// external writes past `num_edges`/`num_nodes` would break that
/// invariant.
#[derive(Clone, Debug)]
pub struct PaddedGraph {
    pub max_nodes: usize,
    pub max_edges: usize,
    pub src: Vec<i32>,
    pub dst: Vec<i32>,
    pub coef: Vec<f32>,
    pub selfcoef: Vec<f32>,
    /// Nodes actually valid in the current contents.
    pub num_nodes: usize,
    pub num_edges: usize,
    /// Dirty high-water marks: entries beyond these are known-zero.
    edge_hwm: usize,
    node_hwm: usize,
}

impl PaddedGraph {
    pub fn new(m: &Manifest) -> Self {
        PaddedGraph {
            max_nodes: m.max_nodes,
            max_edges: m.max_edges,
            src: vec![0; m.max_edges],
            dst: vec![0; m.max_edges],
            coef: vec![0.0; m.max_edges],
            selfcoef: vec![0.0; m.max_nodes],
            num_nodes: 0,
            num_edges: 0,
            edge_hwm: 0,
            node_hwm: 0,
        }
    }

    /// Fill the buffers from a snapshot; errors if it exceeds the budget.
    /// Bulk copies plus tail zeroing bounded by the high-water mark —
    /// allocation-free and O(edges of this and the previous snapshot),
    /// not O(max_edges).
    pub fn fill(&mut self, snap: &Snapshot) -> Result<()> {
        let n = snap.num_nodes();
        let e = snap.num_edges();
        if n > self.max_nodes {
            return Err(Error::Budget { what: "nodes", got: n, max: self.max_nodes });
        }
        if e > self.max_edges {
            return Err(Error::Budget { what: "edges", got: e, max: self.max_edges });
        }
        self.src[..e].copy_from_slice(ids_as_i32(&snap.src));
        self.dst[..e].copy_from_slice(ids_as_i32(&snap.dst));
        self.coef[..e].copy_from_slice(&snap.coef);
        if self.edge_hwm > e {
            // only the previously-dirty tail needs re-zeroing
            self.src[e..self.edge_hwm].fill(0);
            self.dst[e..self.edge_hwm].fill(0);
            self.coef[e..self.edge_hwm].fill(0.0);
        }
        self.edge_hwm = e;
        self.selfcoef[..n].copy_from_slice(&snap.selfcoef);
        if self.node_hwm > n {
            self.selfcoef[n..self.node_hwm].fill(0.0);
        }
        self.node_hwm = n;
        self.num_nodes = n;
        self.num_edges = e;
        Ok(())
    }
}

/// One recyclable staging buffer for the three-stage pipeline: the
/// padded graph arrays, the padded feature matrix, and the snapshot's
/// destination-major CSR — everything the producer-side stage can
/// materialise ahead of inference.  The CSR is rebuilt in place per
/// stage (PJRT execution ignores it; the pure-Rust mirror, cross-checks
/// and CPU baselines consume it through `numerics::spmm`).
#[derive(Clone, Debug)]
pub struct StagingSlot {
    pub graph: PaddedGraph,
    /// Padded features, `[max_nodes × in_dim]` row-major.
    pub x: Vec<f32>,
    /// In-edges grouped by destination, rebuilt in place per stage.
    pub csr: SnapshotCsr,
    in_dim: usize,
    /// Feature rows possibly nonzero from a previous stage.
    x_hwm: usize,
    /// Delta-staging bookkeeping: raw id of each currently-staged
    /// feature row (local order) and the reverse map — empty after a
    /// non-delta stage, so a following [`Self::stage_delta`] refetches
    /// everything.
    x_raws: Vec<u32>,
    x_map: HashMap<u32, u32>,
    /// Double buffer for delta layout transitions, and the row count its
    /// stale contents may extend to.
    x_scratch: Vec<f32>,
    scratch_hwm: usize,
    plan: DeltaPlan,
}

impl StagingSlot {
    pub fn new(m: &Manifest) -> Self {
        StagingSlot {
            graph: PaddedGraph::new(m),
            x: vec![0.0; m.max_nodes * m.in_dim],
            csr: SnapshotCsr::new(),
            in_dim: m.in_dim,
            x_hwm: 0,
            x_raws: Vec::new(),
            x_map: HashMap::new(),
            x_scratch: vec![0.0; m.max_nodes * m.in_dim],
            scratch_hwm: 0,
            plan: DeltaPlan::new(),
        }
    }

    /// Stage one snapshot: pad the graph arrays, rebuild the CSR, and
    /// materialise features row by row via `features(raw_id, row_out)`.
    /// Allocation-free at steady state.
    pub fn stage(
        &mut self,
        snap: &Snapshot,
        mut features: impl FnMut(u32, &mut [f32]),
    ) -> Result<()> {
        self.graph.fill(snap)?;
        self.csr.rebuild(snap);
        self.x_raws.clear();
        self.x_map.clear();
        let d = self.in_dim;
        for (local, raw) in snap.renumber.iter() {
            let i = local as usize * d;
            features(raw, &mut self.x[i..i + d]);
        }
        let n = snap.num_nodes();
        if self.x_hwm > n {
            self.x[n * d..self.x_hwm * d].fill(0.0);
        }
        self.x_hwm = n;
        Ok(())
    }

    /// Delta-aware [`Self::stage`] (the feature-side §VI win): rows for
    /// nodes shared with the previously staged snapshot are moved to
    /// their new local position instead of re-materialised — `features`
    /// is only invoked for arriving nodes.  Requires `features` to be a
    /// pure function of the raw id (true for the DRAM-resident feature
    /// store this models); guarded by the same [`DeltaPlan`] the
    /// resident-state path uses.  Returns the overlap stats so callers
    /// can report the measured reuse fraction.  Allocation-free at
    /// steady state.
    ///
    /// The delta is relative to **this slot's** previous stage.  Pool
    /// slots recycled round-robin by the staged pipeline see every
    /// POOL-th snapshot; for true adjacent-snapshot deltas keep one
    /// dedicated slot as a persistent cache and copy its rows into the
    /// pool slot via [`Self::stage_from_rows`] (see
    /// `examples/e2e_serve.rs`).
    pub fn stage_delta(
        &mut self,
        snap: &Snapshot,
        features: impl FnMut(u32, &mut [f32]),
    ) -> Result<DeltaStats> {
        self.graph.fill(snap)?;
        self.csr.rebuild(snap);
        Ok(self.stage_features_delta(snap, features))
    }

    /// Edit-stream [`Self::stage`]: the graph step arrives as an edge
    /// diff over a stable node layout (`EdgeDelta` — see
    /// `datasets::synth::edit_stream`), so the cached CSR is **patched**
    /// via [`SnapshotCsr::rebuild_delta`] (full counting-sort fallback
    /// past [`DELTA_CHURN_MAX`] or on any contract violation — the
    /// returned [`CsrRebuild`] reports which path ran), and when the
    /// raw-id layout is unchanged the staged feature rows are already
    /// current and all feature movement is skipped (the
    /// `DeltaPlan::layout_stable` condition, checked directly against
    /// this slot's bookkeeping).  Falls back to delta feature staging on
    /// any layout change.  Allocation-free at steady state (asserted by
    /// `tests/alloc_hotpath.rs`).
    pub fn stage_edit(
        &mut self,
        snap: &Snapshot,
        delta: &EdgeDelta,
        features: impl FnMut(u32, &mut [f32]),
    ) -> Result<CsrRebuild> {
        self.graph.fill(snap)?;
        let kind = self.csr.rebuild_delta(snap, delta, DELTA_CHURN_MAX);
        if self.x_raws.as_slice() != snap.renumber.raws() {
            self.stage_features_delta(snap, features);
        }
        Ok(kind)
    }

    /// Shared feature tail of [`Self::stage_delta`]/[`Self::stage_edit`]:
    /// move shared rows into the double buffer, fetch arrivals, swap,
    /// and refresh the raw-id bookkeeping.
    fn stage_features_delta(
        &mut self,
        snap: &Snapshot,
        mut features: impl FnMut(u32, &mut [f32]),
    ) -> DeltaStats {
        let d = self.in_dim;
        let n = snap.num_nodes(); // within max_nodes: graph.fill checked
        {
            let (plan, raws, map) = (&mut self.plan, &self.x_raws, &self.x_map);
            plan.build(raws, |r| map.get(&r).copied(), &snap.renumber);
        }
        for &(i, j) in &self.plan.shared {
            let (dst, src) = (i as usize * d, j as usize * d);
            self.x_scratch[dst..dst + d].copy_from_slice(&self.x[src..src + d]);
        }
        for &(i, raw) in &self.plan.fetch {
            let dst = i as usize * d;
            features(raw, &mut self.x_scratch[dst..dst + d]);
        }
        if self.scratch_hwm > n {
            self.x_scratch[n * d..self.scratch_hwm * d].fill(0.0);
        }
        std::mem::swap(&mut self.x, &mut self.x_scratch);
        self.scratch_hwm = self.x_hwm;
        self.x_hwm = n;
        self.x_raws.clear();
        self.x_raws.extend_from_slice(snap.renumber.raws());
        self.x_map.clear();
        for (local, raw) in snap.renumber.iter() {
            self.x_map.insert(raw, local);
        }
        self.plan.stats()
    }

    /// Stage from an already-materialised dense `[n × in_dim]` feature
    /// matrix (e.g. a pipeline payload computed on the prepare thread).
    pub fn stage_from_rows(&mut self, snap: &Snapshot, x: &[f32]) -> Result<()> {
        self.graph.fill(snap)?;
        self.csr.rebuild(snap);
        self.x_raws.clear();
        self.x_map.clear();
        let d = self.in_dim;
        let n = snap.num_nodes();
        debug_assert_eq!(x.len(), n * d, "feature matrix must be [num_nodes × in_dim]");
        self.x[..n * d].copy_from_slice(x);
        if self.x_hwm > n {
            self.x[n * d..self.x_hwm * d].fill(0.0);
        }
        self.x_hwm = n;
        Ok(())
    }

    /// Adopt everything another slot already staged for `snap`: pad the
    /// graph arrays, **copy** the donor's CSR (three `memcpy`s via
    /// [`SnapshotCsr::copy_from`] — no counting sort), and copy its
    /// staged feature rows.  This is how the serve-side edit path keeps
    /// round-robin-recycled pool slots current: the tenant's persistent
    /// cache slot sees every step in order (so its CSR can take the
    /// adjacent-step patch), then the pool slot adopts the result
    /// wholesale.  Allocation-free at steady state.
    pub fn adopt_staged(&mut self, snap: &Snapshot, from: &StagingSlot) -> Result<()> {
        self.graph.fill(snap)?;
        self.csr.copy_from(&from.csr);
        self.x_raws.clear();
        self.x_map.clear();
        let d = self.in_dim;
        let n = snap.num_nodes();
        debug_assert_eq!(d, from.in_dim, "adopting across manifests");
        self.x[..n * d].copy_from_slice(&from.x[..n * d]);
        if self.x_hwm > n {
            self.x[n * d..self.x_hwm * d].fill(0.0);
        }
        self.x_hwm = n;
        Ok(())
    }
}

/// Pad a dense [n × dim] row-major buffer to [max_nodes × dim], reusing
/// `out`.
pub fn pad_rows(data: &[f32], n: usize, dim: usize, max_nodes: usize, out: &mut Vec<f32>) {
    debug_assert_eq!(data.len(), n * dim);
    out.resize(max_nodes * dim, 0.0);
    out[..n * dim].copy_from_slice(data);
    for v in &mut out[n * dim..] {
        *v = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::RenumberTable;

    fn manifest() -> Manifest {
        Manifest { max_nodes: 8, max_edges: 6, in_dim: 4, hidden_dim: 4, out_dim: 4 }
    }

    fn snap(n: usize, e: usize) -> Snapshot {
        let pairs: Vec<(u32, u32)> = (0..n as u32 - 1).map(|i| (i, i + 1)).collect();
        let mut pairs = pairs;
        if pairs.is_empty() {
            pairs.push((0, 0));
        }
        Snapshot {
            index: 0,
            src: vec![0; e],
            dst: vec![(n - 1) as u32; e],
            coef: vec![0.25; e],
            selfcoef: vec![0.5; n],
            renumber: RenumberTable::build(pairs.into_iter()),
            t_start: 0,
        }
    }

    #[test]
    fn fill_pads_tail_with_zeros() {
        let mut pg = PaddedGraph::new(&manifest());
        pg.fill(&snap(3, 2)).unwrap();
        assert_eq!(pg.num_nodes, 3);
        assert_eq!(pg.num_edges, 2);
        assert_eq!(&pg.coef[2..], &[0.0; 4]);
        assert_eq!(&pg.selfcoef[3..], &[0.0; 5]);
        assert_eq!(pg.dst[0], 2);
    }

    #[test]
    fn refill_clears_previous_contents() {
        let mut pg = PaddedGraph::new(&manifest());
        pg.fill(&snap(8, 6)).unwrap();
        pg.fill(&snap(2, 1)).unwrap();
        assert!(pg.src[1..].iter().all(|&v| v == 0));
        assert!(pg.coef[1..].iter().all(|&v| v == 0.0));
        assert!(pg.selfcoef[2..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn hwm_grow_shrink_grow_stays_clean() {
        let mut pg = PaddedGraph::new(&manifest());
        pg.fill(&snap(8, 6)).unwrap();
        pg.fill(&snap(2, 1)).unwrap();
        pg.fill(&snap(4, 3)).unwrap();
        // tail beyond 3 edges / 4 nodes must be zero after the regrow
        assert!(pg.src[3..].iter().all(|&v| v == 0));
        assert!(pg.dst[3..].iter().all(|&v| v == 0));
        assert!(pg.coef[3..].iter().all(|&v| v == 0.0));
        assert!(pg.selfcoef[4..].iter().all(|&v| v == 0.0));
        assert_eq!(pg.num_edges, 3);
        assert_eq!(pg.num_nodes, 4);
    }

    #[test]
    fn staging_slot_pads_features_and_zeroes_tail() {
        let m = manifest();
        let mut slot = StagingSlot::new(&m);
        slot.stage(&snap(4, 3), |raw, row| row.fill(raw as f32 + 1.0)).unwrap();
        assert!(slot.x[..4 * m.in_dim].iter().all(|&v| v != 0.0));
        assert!(slot.x[4 * m.in_dim..].iter().all(|&v| v == 0.0));
        slot.stage(&snap(2, 1), |_raw, row| row.fill(0.5)).unwrap();
        assert!(slot.x[..2 * m.in_dim].iter().all(|&v| v == 0.5));
        assert!(slot.x[2 * m.in_dim..].iter().all(|&v| v == 0.0));
        assert_eq!(slot.graph.num_nodes, 2);
    }

    #[test]
    fn staging_slot_caches_destination_csr() {
        let m = manifest();
        let mut slot = StagingSlot::new(&m);
        let s = snap(4, 3); // 3 edges, all into node 3
        slot.stage(&s, |_raw, row| row.fill(1.0)).unwrap();
        assert_eq!(slot.csr.num_nodes(), 4);
        assert_eq!(slot.csr.num_edges(), 3);
        assert_eq!(slot.csr.row(3).0.len(), 3);
        assert_eq!(slot.csr.row(0).0.len(), 0);
    }

    #[test]
    fn stage_delta_matches_full_stage_bitwise() {
        use crate::graph::RenumberTable;
        let m = manifest();
        let mut full = StagingSlot::new(&m);
        let mut delta = StagingSlot::new(&m);
        // deterministic per-raw features, counting invocations
        let mut calls_full = 0usize;
        let mut calls_delta = 0usize;
        let feats = |raw: u32, row: &mut [f32]| {
            for (k, v) in row.iter_mut().enumerate() {
                *v = raw as f32 + k as f32 * 0.25 + 1.0;
            }
        };
        // a sequence with heavy overlap, then shrink, then regrow
        let windows: Vec<Vec<(u32, u32)>> = vec![
            vec![(1, 2), (2, 3), (3, 4)],
            vec![(2, 3), (3, 5)],
            vec![(5, 6)],
            vec![(1, 2), (5, 6), (6, 7)],
        ];
        let (mut shared_total, mut nodes_total) = (0usize, 0usize);
        for pairs in &windows {
            let renumber = RenumberTable::build(pairs.iter().copied());
            let n = renumber.len();
            let s = Snapshot {
                index: 0,
                src: vec![0],
                dst: vec![(n - 1) as u32],
                coef: vec![0.25],
                selfcoef: vec![0.5; n],
                renumber,
                t_start: 0,
            };
            full.stage(&s, |raw, row| {
                calls_full += 1;
                feats(raw, row);
            })
            .unwrap();
            let st = delta
                .stage_delta(&s, |raw, row| {
                    calls_delta += 1;
                    feats(raw, row);
                })
                .unwrap();
            shared_total += st.shared_nodes;
            nodes_total += st.nodes;
            assert_eq!(
                full.x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                delta.x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "staged features diverged"
            );
        }
        assert!(shared_total > 0 && shared_total < nodes_total);
        // the delta path must have skipped exactly the shared rows
        assert_eq!(calls_delta, calls_full - shared_total);
    }

    #[test]
    fn stage_after_stage_delta_and_back_is_consistent() {
        let m = manifest();
        let mut slot = StagingSlot::new(&m);
        let feats = |raw: u32, row: &mut [f32]| row.fill(raw as f32 + 1.0);
        let s1 = snap(4, 3);
        let s2 = snap(6, 4);
        slot.stage_delta(&s1, feats).unwrap();
        slot.stage(&s2, feats).unwrap(); // invalidates delta bookkeeping
        let st = slot.stage_delta(&s1, feats).unwrap();
        // after a non-delta stage everything must be refetched
        assert_eq!(st.shared_nodes, 0);
        assert_eq!(st.new_nodes, s1.num_nodes());
        let mut want = StagingSlot::new(&m);
        want.stage(&s1, feats).unwrap();
        assert_eq!(slot.x, want.x);
    }

    #[test]
    fn stage_edit_matches_full_stage_and_skips_feature_work() {
        use crate::datasets::synth::edit_stream;
        use crate::graph::CsrRebuild;
        use crate::testutil::Pcg32;
        let m = Manifest { max_nodes: 16, max_edges: 64, in_dim: 3, hidden_dim: 4, out_dim: 4 };
        let mut rng = Pcg32::seeded(44);
        let steps = edit_stream(&mut rng, 16, 48, 5, 0.25);
        let feats = |raw: u32, row: &mut [f32]| row.fill(raw as f32 + 1.0);
        let mut edit = StagingSlot::new(&m);
        let mut full = StagingSlot::new(&m);
        let mut fetches = 0usize;
        for (i, st) in steps.iter().enumerate() {
            full.stage(&st.snap, feats).unwrap();
            let kind = edit
                .stage_edit(&st.snap, &st.delta, |raw, row| {
                    fetches += 1;
                    feats(raw, row);
                })
                .unwrap();
            if i == 0 {
                assert_eq!(kind, CsrRebuild::Full, "bootstrap step is a full rebuild");
            } else {
                assert_eq!(kind, CsrRebuild::Patched, "step {i}");
            }
            assert_eq!(
                full.x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                edit.x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "step {i} staged X"
            );
            for r in 0..16 {
                assert_eq!(full.csr.row(r), edit.csr.row(r), "step {i} csr row {r}");
            }
        }
        // the stable layout means feature rows were materialised exactly
        // once, at the bootstrap step
        assert_eq!(fetches, 16);
    }

    #[test]
    fn adopt_staged_matches_direct_stage_bitwise() {
        use crate::datasets::synth::edit_stream;
        use crate::testutil::Pcg32;
        let m = Manifest { max_nodes: 16, max_edges: 64, in_dim: 3, hidden_dim: 4, out_dim: 4 };
        let mut rng = Pcg32::seeded(45);
        let steps = edit_stream(&mut rng, 16, 48, 4, 0.25);
        let feats = |raw: u32, row: &mut [f32]| row.fill(raw as f32 + 1.0);
        let mut cache = StagingSlot::new(&m);
        // a dirty pool slot (staged with something unrelated first)
        let mut pool = StagingSlot::new(&m);
        pool.stage(&steps[2].snap, feats).unwrap();
        for st in &steps {
            cache.stage_edit(&st.snap, &st.delta, feats).unwrap();
            pool.adopt_staged(&st.snap, &cache).unwrap();
            let mut want = StagingSlot::new(&m);
            want.stage(&st.snap, feats).unwrap();
            assert_eq!(
                pool.x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            );
            for r in 0..16 {
                assert_eq!(pool.csr.row(r), want.csr.row(r), "csr row {r}");
            }
            assert_eq!(pool.graph.num_edges, want.graph.num_edges);
            assert_eq!(pool.graph.selfcoef, want.graph.selfcoef);
        }
    }

    #[test]
    fn budget_overflow_rejected() {
        let mut pg = PaddedGraph::new(&manifest());
        let err = pg.fill(&snap(3, 7)).unwrap_err();
        assert!(matches!(err, Error::Budget { what: "edges", .. }));
    }

    #[test]
    fn pad_rows_reuses_buffer() {
        let mut out = Vec::new();
        pad_rows(&[1.0, 2.0, 3.0, 4.0], 2, 2, 4, &mut out);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
        pad_rows(&[5.0, 6.0], 1, 2, 4, &mut out);
        assert_eq!(out, vec![5.0, 6.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    }
}
