//! Snapshot padding to the fixed AOT shapes.
//!
//! The padding contract (shared with `python/compile/model.py`):
//! * padded edges: `src = dst = 0`, `coef = 0.0` → contribute nothing;
//! * padded node rows: `selfcoef = 0.0`; feature/state rows zero;
//! * consumers read back only the first `num_nodes` rows.
//!
//! Buffers are reusable across snapshots (the hot path never
//! reallocates — see EXPERIMENTS.md §Perf).

use crate::error::{Error, Result};
use crate::graph::Snapshot;
use crate::runtime::manifest::Manifest;

/// Reinterpret a `&[u32]` of local node ids as `&[i32]` (same layout;
/// ids are bounded by the node budget, far below 2³¹).
fn ids_as_i32(v: &[u32]) -> &[i32] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const i32, v.len()) }
}

/// Reusable padded buffers for one snapshot's graph arrays.
///
/// Between fills the buffers must be treated as read-only: `fill` tracks
/// a high-water mark so only the previously-dirty tail is re-zeroed, and
/// external writes past `num_edges`/`num_nodes` would break that
/// invariant.
#[derive(Clone, Debug)]
pub struct PaddedGraph {
    pub max_nodes: usize,
    pub max_edges: usize,
    pub src: Vec<i32>,
    pub dst: Vec<i32>,
    pub coef: Vec<f32>,
    pub selfcoef: Vec<f32>,
    /// Nodes actually valid in the current contents.
    pub num_nodes: usize,
    pub num_edges: usize,
    /// Dirty high-water marks: entries beyond these are known-zero.
    edge_hwm: usize,
    node_hwm: usize,
}

impl PaddedGraph {
    pub fn new(m: &Manifest) -> Self {
        PaddedGraph {
            max_nodes: m.max_nodes,
            max_edges: m.max_edges,
            src: vec![0; m.max_edges],
            dst: vec![0; m.max_edges],
            coef: vec![0.0; m.max_edges],
            selfcoef: vec![0.0; m.max_nodes],
            num_nodes: 0,
            num_edges: 0,
            edge_hwm: 0,
            node_hwm: 0,
        }
    }

    /// Fill the buffers from a snapshot; errors if it exceeds the budget.
    /// Bulk copies plus tail zeroing bounded by the high-water mark —
    /// allocation-free and O(edges of this and the previous snapshot),
    /// not O(max_edges).
    pub fn fill(&mut self, snap: &Snapshot) -> Result<()> {
        let n = snap.num_nodes();
        let e = snap.num_edges();
        if n > self.max_nodes {
            return Err(Error::Budget { what: "nodes", got: n, max: self.max_nodes });
        }
        if e > self.max_edges {
            return Err(Error::Budget { what: "edges", got: e, max: self.max_edges });
        }
        self.src[..e].copy_from_slice(ids_as_i32(&snap.src));
        self.dst[..e].copy_from_slice(ids_as_i32(&snap.dst));
        self.coef[..e].copy_from_slice(&snap.coef);
        if self.edge_hwm > e {
            // only the previously-dirty tail needs re-zeroing
            self.src[e..self.edge_hwm].fill(0);
            self.dst[e..self.edge_hwm].fill(0);
            self.coef[e..self.edge_hwm].fill(0.0);
        }
        self.edge_hwm = e;
        self.selfcoef[..n].copy_from_slice(&snap.selfcoef);
        if self.node_hwm > n {
            self.selfcoef[n..self.node_hwm].fill(0.0);
        }
        self.node_hwm = n;
        self.num_nodes = n;
        self.num_edges = e;
        Ok(())
    }
}

/// One recyclable staging buffer for the three-stage pipeline: the
/// padded graph arrays plus the padded feature matrix — everything the
/// producer-side stage can materialise ahead of inference.
#[derive(Clone, Debug)]
pub struct StagingSlot {
    pub graph: PaddedGraph,
    /// Padded features, `[max_nodes × in_dim]` row-major.
    pub x: Vec<f32>,
    in_dim: usize,
    /// Feature rows possibly nonzero from a previous stage.
    x_hwm: usize,
}

impl StagingSlot {
    pub fn new(m: &Manifest) -> Self {
        StagingSlot {
            graph: PaddedGraph::new(m),
            x: vec![0.0; m.max_nodes * m.in_dim],
            in_dim: m.in_dim,
            x_hwm: 0,
        }
    }

    /// Stage one snapshot: pad the graph arrays and materialise features
    /// row by row via `features(raw_id, row_out)`.  Allocation-free once
    /// constructed.
    pub fn stage(
        &mut self,
        snap: &Snapshot,
        mut features: impl FnMut(u32, &mut [f32]),
    ) -> Result<()> {
        self.graph.fill(snap)?;
        let d = self.in_dim;
        for (local, raw) in snap.renumber.iter() {
            let i = local as usize * d;
            features(raw, &mut self.x[i..i + d]);
        }
        let n = snap.num_nodes();
        if self.x_hwm > n {
            self.x[n * d..self.x_hwm * d].fill(0.0);
        }
        self.x_hwm = n;
        Ok(())
    }

    /// Stage from an already-materialised dense `[n × in_dim]` feature
    /// matrix (e.g. a pipeline payload computed on the prepare thread).
    pub fn stage_from_rows(&mut self, snap: &Snapshot, x: &[f32]) -> Result<()> {
        self.graph.fill(snap)?;
        let d = self.in_dim;
        let n = snap.num_nodes();
        debug_assert_eq!(x.len(), n * d, "feature matrix must be [num_nodes × in_dim]");
        self.x[..n * d].copy_from_slice(x);
        if self.x_hwm > n {
            self.x[n * d..self.x_hwm * d].fill(0.0);
        }
        self.x_hwm = n;
        Ok(())
    }
}

/// Pad a dense [n × dim] row-major buffer to [max_nodes × dim], reusing
/// `out`.
pub fn pad_rows(data: &[f32], n: usize, dim: usize, max_nodes: usize, out: &mut Vec<f32>) {
    debug_assert_eq!(data.len(), n * dim);
    out.resize(max_nodes * dim, 0.0);
    out[..n * dim].copy_from_slice(data);
    for v in &mut out[n * dim..] {
        *v = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::RenumberTable;

    fn manifest() -> Manifest {
        Manifest { max_nodes: 8, max_edges: 6, in_dim: 4, hidden_dim: 4, out_dim: 4 }
    }

    fn snap(n: usize, e: usize) -> Snapshot {
        let pairs: Vec<(u32, u32)> = (0..n as u32 - 1).map(|i| (i, i + 1)).collect();
        let mut pairs = pairs;
        if pairs.is_empty() {
            pairs.push((0, 0));
        }
        Snapshot {
            index: 0,
            src: vec![0; e],
            dst: vec![(n - 1) as u32; e],
            coef: vec![0.25; e],
            selfcoef: vec![0.5; n],
            renumber: RenumberTable::build(pairs.into_iter()),
            t_start: 0,
        }
    }

    #[test]
    fn fill_pads_tail_with_zeros() {
        let mut pg = PaddedGraph::new(&manifest());
        pg.fill(&snap(3, 2)).unwrap();
        assert_eq!(pg.num_nodes, 3);
        assert_eq!(pg.num_edges, 2);
        assert_eq!(&pg.coef[2..], &[0.0; 4]);
        assert_eq!(&pg.selfcoef[3..], &[0.0; 5]);
        assert_eq!(pg.dst[0], 2);
    }

    #[test]
    fn refill_clears_previous_contents() {
        let mut pg = PaddedGraph::new(&manifest());
        pg.fill(&snap(8, 6)).unwrap();
        pg.fill(&snap(2, 1)).unwrap();
        assert!(pg.src[1..].iter().all(|&v| v == 0));
        assert!(pg.coef[1..].iter().all(|&v| v == 0.0));
        assert!(pg.selfcoef[2..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn hwm_grow_shrink_grow_stays_clean() {
        let mut pg = PaddedGraph::new(&manifest());
        pg.fill(&snap(8, 6)).unwrap();
        pg.fill(&snap(2, 1)).unwrap();
        pg.fill(&snap(4, 3)).unwrap();
        // tail beyond 3 edges / 4 nodes must be zero after the regrow
        assert!(pg.src[3..].iter().all(|&v| v == 0));
        assert!(pg.dst[3..].iter().all(|&v| v == 0));
        assert!(pg.coef[3..].iter().all(|&v| v == 0.0));
        assert!(pg.selfcoef[4..].iter().all(|&v| v == 0.0));
        assert_eq!(pg.num_edges, 3);
        assert_eq!(pg.num_nodes, 4);
    }

    #[test]
    fn staging_slot_pads_features_and_zeroes_tail() {
        let m = manifest();
        let mut slot = StagingSlot::new(&m);
        slot.stage(&snap(4, 3), |raw, row| row.fill(raw as f32 + 1.0)).unwrap();
        assert!(slot.x[..4 * m.in_dim].iter().all(|&v| v != 0.0));
        assert!(slot.x[4 * m.in_dim..].iter().all(|&v| v == 0.0));
        slot.stage(&snap(2, 1), |_raw, row| row.fill(0.5)).unwrap();
        assert!(slot.x[..2 * m.in_dim].iter().all(|&v| v == 0.5));
        assert!(slot.x[2 * m.in_dim..].iter().all(|&v| v == 0.0));
        assert_eq!(slot.graph.num_nodes, 2);
    }

    #[test]
    fn budget_overflow_rejected() {
        let mut pg = PaddedGraph::new(&manifest());
        let err = pg.fill(&snap(3, 7)).unwrap_err();
        assert!(matches!(err, Error::Budget { what: "edges", .. }));
    }

    #[test]
    fn pad_rows_reuses_buffer() {
        let mut out = Vec::new();
        pad_rows(&[1.0, 2.0, 3.0, 4.0], 2, 2, 4, &mut out);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
        pad_rows(&[5.0, 6.0], 1, 2, 4, &mut out);
        assert_eq!(out, vec![5.0, 6.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    }
}
