//! Snapshot padding to the fixed AOT shapes.
//!
//! The padding contract (shared with `python/compile/model.py`):
//! * padded edges: `src = dst = 0`, `coef = 0.0` → contribute nothing;
//! * padded node rows: `selfcoef = 0.0`; feature/state rows zero;
//! * consumers read back only the first `num_nodes` rows.
//!
//! Buffers are reusable across snapshots (the hot path never
//! reallocates — see EXPERIMENTS.md §Perf).

use crate::error::{Error, Result};
use crate::graph::Snapshot;
use crate::runtime::manifest::Manifest;

/// Reusable padded buffers for one snapshot's graph arrays.
#[derive(Clone, Debug)]
pub struct PaddedGraph {
    pub max_nodes: usize,
    pub max_edges: usize,
    pub src: Vec<i32>,
    pub dst: Vec<i32>,
    pub coef: Vec<f32>,
    pub selfcoef: Vec<f32>,
    /// Nodes actually valid in the current contents.
    pub num_nodes: usize,
    pub num_edges: usize,
}

impl PaddedGraph {
    pub fn new(m: &Manifest) -> Self {
        PaddedGraph {
            max_nodes: m.max_nodes,
            max_edges: m.max_edges,
            src: vec![0; m.max_edges],
            dst: vec![0; m.max_edges],
            coef: vec![0.0; m.max_edges],
            selfcoef: vec![0.0; m.max_nodes],
            num_nodes: 0,
            num_edges: 0,
        }
    }

    /// Fill the buffers from a snapshot; errors if it exceeds the budget.
    pub fn fill(&mut self, snap: &Snapshot) -> Result<()> {
        let n = snap.num_nodes();
        let e = snap.num_edges();
        if n > self.max_nodes {
            return Err(Error::Budget { what: "nodes", got: n, max: self.max_nodes });
        }
        if e > self.max_edges {
            return Err(Error::Budget { what: "edges", got: e, max: self.max_edges });
        }
        for i in 0..e {
            self.src[i] = snap.src[i] as i32;
            self.dst[i] = snap.dst[i] as i32;
            self.coef[i] = snap.coef[i];
        }
        // zero the padding tail (previous contents may linger)
        for i in e..self.max_edges {
            self.src[i] = 0;
            self.dst[i] = 0;
            self.coef[i] = 0.0;
        }
        self.selfcoef[..n].copy_from_slice(&snap.selfcoef);
        for v in &mut self.selfcoef[n..] {
            *v = 0.0;
        }
        self.num_nodes = n;
        self.num_edges = e;
        Ok(())
    }
}

/// Pad a dense [n × dim] row-major buffer to [max_nodes × dim], reusing
/// `out`.
pub fn pad_rows(data: &[f32], n: usize, dim: usize, max_nodes: usize, out: &mut Vec<f32>) {
    debug_assert_eq!(data.len(), n * dim);
    out.resize(max_nodes * dim, 0.0);
    out[..n * dim].copy_from_slice(data);
    for v in &mut out[n * dim..] {
        *v = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::RenumberTable;

    fn manifest() -> Manifest {
        Manifest { max_nodes: 8, max_edges: 6, in_dim: 4, hidden_dim: 4, out_dim: 4 }
    }

    fn snap(n: usize, e: usize) -> Snapshot {
        let pairs: Vec<(u32, u32)> = (0..n as u32 - 1).map(|i| (i, i + 1)).collect();
        let mut pairs = pairs;
        if pairs.is_empty() {
            pairs.push((0, 0));
        }
        Snapshot {
            index: 0,
            src: vec![0; e],
            dst: vec![(n - 1) as u32; e],
            coef: vec![0.25; e],
            selfcoef: vec![0.5; n],
            renumber: RenumberTable::build(pairs.into_iter()),
            t_start: 0,
        }
    }

    #[test]
    fn fill_pads_tail_with_zeros() {
        let mut pg = PaddedGraph::new(&manifest());
        pg.fill(&snap(3, 2)).unwrap();
        assert_eq!(pg.num_nodes, 3);
        assert_eq!(pg.num_edges, 2);
        assert_eq!(&pg.coef[2..], &[0.0; 4]);
        assert_eq!(&pg.selfcoef[3..], &[0.0; 5]);
        assert_eq!(pg.dst[0], 2);
    }

    #[test]
    fn refill_clears_previous_contents() {
        let mut pg = PaddedGraph::new(&manifest());
        pg.fill(&snap(8, 6)).unwrap();
        pg.fill(&snap(2, 1)).unwrap();
        assert!(pg.src[1..].iter().all(|&v| v == 0));
        assert!(pg.coef[1..].iter().all(|&v| v == 0.0));
        assert!(pg.selfcoef[2..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn budget_overflow_rejected() {
        let mut pg = PaddedGraph::new(&manifest());
        let err = pg.fill(&snap(3, 7)).unwrap_err();
        assert!(matches!(err, Error::Budget { what: "edges", .. }));
    }

    #[test]
    fn pad_rows_reuses_buffer() {
        let mut out = Vec::new();
        pad_rows(&[1.0, 2.0, 3.0, 4.0], 2, 2, 4, &mut out);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
        pad_rows(&[5.0, 6.0], 1, 2, 4, &mut out);
        assert_eq!(out, vec![5.0, 6.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    }
}
