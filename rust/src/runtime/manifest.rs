//! AOT artifact manifest parser (`artifacts/manifest.txt`).
//!
//! The manifest is key=value text (no serde in the offline crate set);
//! it records the padded shapes the artifacts were lowered at so the
//! runtime can validate snapshots against the AOT budget.

use crate::error::{Error, Result};

/// Parsed manifest.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Manifest {
    pub max_nodes: usize,
    pub max_edges: usize,
    pub in_dim: usize,
    pub hidden_dim: usize,
    pub out_dim: usize,
}

impl Manifest {
    /// Parse from the manifest file's text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut kv = std::collections::HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some((k, v)) = line.split_once('=') {
                kv.insert(k.to_string(), v.to_string());
            }
        }
        let get = |k: &str| -> Result<usize> {
            kv.get(k)
                .ok_or_else(|| Error::Artifact(format!("manifest missing key {k}")))?
                .parse()
                .map_err(|e| Error::Artifact(format!("manifest key {k}: {e}")))
        };
        Ok(Manifest {
            max_nodes: get("max_nodes")?,
            max_edges: get("max_edges")?,
            in_dim: get("in_dim")?,
            hidden_dim: get("hidden_dim")?,
            out_dim: get("out_dim")?,
        })
    }

    /// Load from `<dir>/manifest.txt`.
    pub fn load(dir: &str) -> Result<Manifest> {
        let path = format!("{dir}/manifest.txt");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Artifact(format!("{path}: {e} (run `make artifacts`)")))?;
        Self::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "# comment\nmax_nodes=608\nmax_edges=1728\n\
        in_dim=32\nhidden_dim=32\nout_dim=32\nextra.key=ignored\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(
            m,
            Manifest {
                max_nodes: 608,
                max_edges: 1728,
                in_dim: 32,
                hidden_dim: 32,
                out_dim: 32
            }
        );
    }

    #[test]
    fn missing_key_is_error() {
        let e = Manifest::parse("max_nodes=1\n").unwrap_err();
        assert!(e.to_string().contains("missing key"));
    }

    #[test]
    fn bad_value_is_error() {
        let text = SAMPLE.replace("608", "not-a-number");
        assert!(Manifest::parse(&text).is_err());
    }

    #[test]
    fn load_reports_make_hint_when_absent() {
        let e = Manifest::load("/nonexistent-dir").unwrap_err();
        assert!(e.to_string().contains("make artifacts"));
    }
}
