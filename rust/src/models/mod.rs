//! DGNN model configurations and parameter initialisation.
//!
//! Two representative models, exactly the paper's choices (§V-A):
//!
//! * [`ModelKind::EvolveGcn`] — weights-evolved DGNN (Table I row 3);
//!   GCN spatial encoder + matrix-GRU weight evolution.  Base model for
//!   DGNN-Booster **V1**.
//! * [`ModelKind::GcrnM2`] — integrated DGNN (Table I row 2); graph-conv
//!   LSTM.  Base model for DGNN-Booster **V2**.
//!
//! Plus [`ModelKind::GcrnM1`] (the stacked Table I row 1 variant) and a
//! fourth family beyond the paper's three: [`ModelKind::Tgat`], a
//! TGAT-style temporal-attention DGNN (cosine time-encoded neighbor
//! attention between Q/K/V and output projections) that proves the
//! serve stack generalises past RNN-flavoured models.
//!
//! Parameters are generated deterministically from a seed with the same
//! scheme on the Rust and (via the e2e driver feeding them in) HLO side,
//! so numerics cross-check bit-for-bit inputs.

use crate::testutil::Pcg32;

/// Which DGNN is being run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelKind {
    /// Weights-evolved DGNN (EvolveGCN-O): GCN weights evolved by a GRU.
    EvolveGcn,
    /// Stacked DGNN (GCRN-M1): GCN encoder feeding a dense LSTM.
    GcrnM1,
    /// Integrated DGNN (GCRN-M2): graph-convolutional LSTM.
    GcrnM2,
    /// Temporal-attention DGNN (TGAT-style): Q/K/V projections, cosine
    /// time-encoded neighbor attention, output projection.  Stateless
    /// across steps (attention re-reads the time channel per snapshot).
    Tgat,
}

/// The three discrete-time DGNN dataflow classes of the paper's Table I.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataflowType {
    /// GNN→RNN within a step; GNNs of different steps independent.
    Stacked,
    /// RNN output feeds the next step's GNN (H/C recurrent per node).
    Integrated,
    /// RNN evolves the GNN weights; GNNs of different steps independent.
    WeightsEvolved,
}

impl ModelKind {
    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::EvolveGcn => "EvolveGCN",
            ModelKind::GcrnM1 => "GCRN-M1",
            ModelKind::GcrnM2 => "GCRN-M2",
            ModelKind::Tgat => "TGAT",
        }
    }

    /// Table I row of this model.
    pub fn dataflow(&self) -> DataflowType {
        match self {
            ModelKind::EvolveGcn => DataflowType::WeightsEvolved,
            ModelKind::GcrnM1 => DataflowType::Stacked,
            ModelKind::GcrnM2 => DataflowType::Integrated,
            // attention is a spatial encoder per step; steps independent
            ModelKind::Tgat => DataflowType::Stacked,
        }
    }

    /// Which DGNN-Booster designs can run this model (Table I columns).
    pub fn supports_version(&self, version: u8) -> bool {
        match self.dataflow() {
            DataflowType::Stacked => version == 1 || version == 2,
            DataflowType::Integrated => version == 2,
            DataflowType::WeightsEvolved => version == 1,
        }
    }

    /// The design the paper evaluates this model on (Table I / §V-A);
    /// stacked models default to V2 (deepest overlap).
    pub fn booster_version(&self) -> u8 {
        match self {
            ModelKind::EvolveGcn => 1,
            ModelKind::GcrnM1 => 2,
            ModelKind::GcrnM2 => 2,
            ModelKind::Tgat => 2,
        }
    }

    pub fn all() -> [ModelKind; 4] {
        [
            ModelKind::EvolveGcn,
            ModelKind::GcrnM1,
            ModelKind::GcrnM2,
            ModelKind::Tgat,
        ]
    }
}

/// Feature dimensions (shared by both models; paper uses one config).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Dims {
    pub in_dim: usize,
    pub hidden_dim: usize,
    pub out_dim: usize,
}

impl Default for Dims {
    fn default() -> Self {
        // EvolveGCN reference defaults for the link-prediction datasets
        Dims {
            in_dim: 32,
            hidden_dim: 32,
            out_dim: 32,
        }
    }
}

/// Matrix-GRU parameter set for one evolved weight matrix
/// (rows×rows gates, rows×cols biases) in the canonical key order
/// wz,uz,bz,wr,ur,br,wh,uh,bh shared with `python/compile/kernels/gru.py`.
#[derive(Clone, Debug)]
pub struct GruParams {
    pub mats: Vec<Vec<f32>>, // 9 matrices, row-major
    pub rows: usize,
    pub cols: usize,
}

impl GruParams {
    pub fn init(rng: &mut Pcg32, rows: usize, cols: usize, scale: f32) -> Self {
        let mut mats = Vec::with_capacity(9);
        for key in 0..9 {
            let is_bias = key % 3 == 2; // bz, br, bh at positions 2,5,8
            let len = if is_bias { rows * cols } else { rows * rows };
            mats.push(rng.normal_vec(len, scale));
        }
        GruParams { mats, rows, cols }
    }
}

/// Full EvolveGCN parameter set.
#[derive(Clone, Debug)]
pub struct EvolveGcnParams {
    pub dims: Dims,
    /// Initial layer-1 weight [in_dim × hidden_dim], row-major.
    pub w1: Vec<f32>,
    /// Initial layer-2 weight [hidden_dim × out_dim].
    pub w2: Vec<f32>,
    pub gru1: GruParams,
    pub gru2: GruParams,
}

impl EvolveGcnParams {
    pub fn init(seed: u64, dims: Dims) -> Self {
        let mut rng = Pcg32::new(seed, 0xE0);
        let scale = 0.3;
        EvolveGcnParams {
            dims,
            w1: rng.normal_vec(dims.in_dim * dims.hidden_dim, scale),
            w2: rng.normal_vec(dims.hidden_dim * dims.out_dim, scale),
            gru1: GruParams::init(&mut rng, dims.in_dim, dims.hidden_dim, 0.1),
            gru2: GruParams::init(&mut rng, dims.hidden_dim, dims.out_dim, 0.1),
        }
    }
}

/// Full GCRN-M1 (stacked) parameter set: 2-layer GCN + dense LSTM.
#[derive(Clone, Debug)]
pub struct GcrnM1Params {
    pub dims: Dims,
    /// GCN layer weights.
    pub w1: Vec<f32>,
    pub w2: Vec<f32>,
    /// LSTM input-side gate weights [out_dim × 4·hidden_dim] (i,f,g,o).
    pub wx: Vec<f32>,
    /// LSTM hidden-side gate weights [hidden_dim × 4·hidden_dim].
    pub wh: Vec<f32>,
    pub b: Vec<f32>,
}

impl GcrnM1Params {
    pub fn init(seed: u64, dims: Dims) -> Self {
        let mut rng = Pcg32::new(seed, 0xC1);
        let scale = 0.3;
        GcrnM1Params {
            dims,
            w1: rng.normal_vec(dims.in_dim * dims.hidden_dim, scale),
            w2: rng.normal_vec(dims.hidden_dim * dims.out_dim, scale),
            wx: rng.normal_vec(dims.out_dim * 4 * dims.hidden_dim, scale),
            wh: rng.normal_vec(dims.hidden_dim * 4 * dims.hidden_dim, scale),
            b: rng.normal_vec(4 * dims.hidden_dim, 0.1),
        }
    }
}

/// Full GCRN-M2 parameter set.
#[derive(Clone, Debug)]
pub struct GcrnM2Params {
    pub dims: Dims,
    /// Input-side gate weights [in_dim × 4·hidden_dim] (gate order i,f,g,o).
    pub wx: Vec<f32>,
    /// Hidden-side gate weights [hidden_dim × 4·hidden_dim].
    pub wh: Vec<f32>,
    /// Gate biases [4·hidden_dim].
    pub b: Vec<f32>,
}

impl GcrnM2Params {
    pub fn init(seed: u64, dims: Dims) -> Self {
        let mut rng = Pcg32::new(seed, 0xC2);
        let scale = 0.3;
        GcrnM2Params {
            dims,
            wx: rng.normal_vec(dims.in_dim * 4 * dims.hidden_dim, scale),
            wh: rng.normal_vec(dims.hidden_dim * 4 * dims.hidden_dim, scale),
            b: rng.normal_vec(4 * dims.hidden_dim, 0.1),
        }
    }
}

/// Number of cosine features in the TGAT time-encoding bank
/// (`score += Σ_j wt[j]·cos(omega[j]·t)`).
pub const TGAT_TIME_DIM: usize = 8;

/// Full TGAT-style parameter set: Q/K/V projections, output projection,
/// and the cosine time-encoding bank.
#[derive(Clone, Debug)]
pub struct TgatParams {
    pub dims: Dims,
    /// Query projection [in_dim × hidden_dim], row-major.
    pub wq: Vec<f32>,
    /// Key projection [in_dim × hidden_dim].
    pub wk: Vec<f32>,
    /// Value projection [in_dim × hidden_dim].
    pub wv: Vec<f32>,
    /// Output projection [hidden_dim × out_dim].
    pub wo: Vec<f32>,
    /// Time-encoding frequencies [TGAT_TIME_DIM].
    pub omega: Vec<f32>,
    /// Time-encoding feature weights [TGAT_TIME_DIM].
    pub wt: Vec<f32>,
}

impl TgatParams {
    pub fn init(seed: u64, dims: Dims) -> Self {
        let mut rng = Pcg32::new(seed, 0x7A);
        let scale = 0.3;
        TgatParams {
            dims,
            wq: rng.normal_vec(dims.in_dim * dims.hidden_dim, scale),
            wk: rng.normal_vec(dims.in_dim * dims.hidden_dim, scale),
            wv: rng.normal_vec(dims.in_dim * dims.hidden_dim, scale),
            wo: rng.normal_vec(dims.hidden_dim * dims.out_dim, scale),
            omega: rng.normal_vec(TGAT_TIME_DIM, 1.0),
            wt: rng.normal_vec(TGAT_TIME_DIM, 0.1),
        }
    }
}

/// Parameter set for any [`ModelKind`] behind one seeded constructor, so
/// every serving surface (examples, CLI `serve`, benches, tests)
/// initialises a model identically.  `serve::session` builds its
/// [`crate::serve::DgnnSession`] implementations from this.
#[derive(Clone, Debug)]
pub enum ModelParams {
    EvolveGcn(EvolveGcnParams),
    GcrnM1(GcrnM1Params),
    GcrnM2(GcrnM2Params),
    Tgat(TgatParams),
}

impl ModelParams {
    pub fn kind(&self) -> ModelKind {
        match self {
            ModelParams::EvolveGcn(_) => ModelKind::EvolveGcn,
            ModelParams::GcrnM1(_) => ModelKind::GcrnM1,
            ModelParams::GcrnM2(_) => ModelKind::GcrnM2,
            ModelParams::Tgat(_) => ModelKind::Tgat,
        }
    }

    pub fn dims(&self) -> Dims {
        match self {
            ModelParams::EvolveGcn(p) => p.dims,
            ModelParams::GcrnM1(p) => p.dims,
            ModelParams::GcrnM2(p) => p.dims,
            ModelParams::Tgat(p) => p.dims,
        }
    }
}

impl ModelKind {
    /// Seeded parameter initialisation for this model (the single path
    /// every caller goes through; see also
    /// `serve::session`'s `ModelKind::build_session`).
    pub fn init_params(self, seed: u64, dims: Dims) -> ModelParams {
        match self {
            ModelKind::EvolveGcn => ModelParams::EvolveGcn(EvolveGcnParams::init(seed, dims)),
            ModelKind::GcrnM1 => ModelParams::GcrnM1(GcrnM1Params::init(seed, dims)),
            ModelKind::GcrnM2 => ModelParams::GcrnM2(GcrnM2Params::init(seed, dims)),
            ModelKind::Tgat => ModelParams::Tgat(TgatParams::init(seed, dims)),
        }
    }
}

/// Deterministic node features keyed by *raw* (global) node id so a node
/// keeps its features across snapshots — the paper's host loads node
/// features from DRAM the same way.
pub fn node_features(raw_id: u32, dim: usize, seed: u64) -> Vec<f32> {
    let mut out = vec![0.0; dim];
    node_features_into(raw_id, seed, &mut out);
    out
}

/// Allocation-free [`node_features`]: writes `out.len()` features for
/// `raw_id` into `out` (the staging hot path's variant).
pub fn node_features_into(raw_id: u32, seed: u64, out: &mut [f32]) {
    let mut rng = Pcg32::new(seed ^ (raw_id as u64).wrapping_mul(0x9E3779B97F4A7C15), 0xFEA7);
    rng.fill_normal(out, 1.0);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_shapes() {
        let d = Dims::default();
        let p = EvolveGcnParams::init(1, d);
        assert_eq!(p.w1.len(), 32 * 32);
        assert_eq!(p.gru1.mats.len(), 9);
        assert_eq!(p.gru1.mats[0].len(), 32 * 32); // wz
        assert_eq!(p.gru1.mats[2].len(), 32 * 32); // bz (rows*cols)
        let g = GcrnM2Params::init(1, d);
        assert_eq!(g.wx.len(), 32 * 128);
        assert_eq!(g.b.len(), 128);
    }

    #[test]
    fn deterministic_params() {
        let a = EvolveGcnParams::init(5, Dims::default());
        let b = EvolveGcnParams::init(5, Dims::default());
        assert_eq!(a.w1, b.w1);
        assert_eq!(a.gru2.mats[7], b.gru2.mats[7]);
    }

    #[test]
    fn node_features_stable_across_calls() {
        let f1 = node_features(42, 32, 9);
        let f2 = node_features(42, 32, 9);
        assert_eq!(f1, f2);
        let f3 = node_features(43, 32, 9);
        assert_ne!(f1, f3);
    }

    #[test]
    fn init_params_matches_per_model_init() {
        let d = Dims::default();
        for kind in ModelKind::all() {
            let p = kind.init_params(7, d);
            assert_eq!(p.kind(), kind);
            assert_eq!(p.dims(), d);
        }
        // the unified constructor must reuse the per-model seeding scheme
        match ModelKind::EvolveGcn.init_params(9, d) {
            ModelParams::EvolveGcn(p) => assert_eq!(p.w1, EvolveGcnParams::init(9, d).w1),
            _ => panic!("wrong variant"),
        }
        match ModelKind::GcrnM2.init_params(9, d) {
            ModelParams::GcrnM2(p) => assert_eq!(p.wx, GcrnM2Params::init(9, d).wx),
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn tgat_param_shapes_and_determinism() {
        let d = Dims::default();
        let p = TgatParams::init(3, d);
        assert_eq!(p.wq.len(), 32 * 32);
        assert_eq!(p.wk.len(), 32 * 32);
        assert_eq!(p.wv.len(), 32 * 32);
        assert_eq!(p.wo.len(), 32 * 32);
        assert_eq!(p.omega.len(), TGAT_TIME_DIM);
        assert_eq!(p.wt.len(), TGAT_TIME_DIM);
        // distinct seeding streams: Q and K projections differ
        assert_ne!(p.wq, p.wk);
        let q = TgatParams::init(3, d);
        assert_eq!(p.wq, q.wq);
        assert_eq!(p.omega, q.omega);
        // the fourth family rides every ModelKind surface
        assert_eq!(ModelKind::Tgat.name(), "TGAT");
        assert_eq!(ModelKind::Tgat.dataflow(), DataflowType::Stacked);
        assert!(ModelKind::Tgat.supports_version(2));
        assert!(ModelKind::all().contains(&ModelKind::Tgat));
    }

    #[test]
    fn gru_bias_shape_nonsquare() {
        let mut rng = Pcg32::seeded(2);
        let p = GruParams::init(&mut rng, 16, 24, 0.1);
        assert_eq!(p.mats[0].len(), 16 * 16);
        assert_eq!(p.mats[2].len(), 16 * 24);
    }
}
