//! # DGNN-Booster — a generic accelerator framework for dynamic-GNN inference
//!
//! Rust reproduction of *DGNN-Booster: A Generic FPGA Accelerator Framework
//! For Dynamic Graph Neural Network Inference* (Chen & Hao, 2023) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordinator: host-side graph
//!   preprocessing (time-splitting, renumbering, COO→CSR), the V1/V2
//!   dataflow schedulers, a cycle-approximate ZCU102 model, CPU/GPU
//!   baseline models, energy accounting, the PJRT runtime that
//!   executes the AOT-compiled model steps, and the [`serve`]
//!   subsystem (unified model sessions + the multi-stream scheduler).
//! * **Layer 2** — JAX per-snapshot model steps (`python/compile/model.py`),
//!   AOT-lowered to HLO text in `artifacts/`.
//! * **Layer 1** — Pallas PE kernels (`python/compile/kernels/`).
//!
//! Python never runs on the request path: after `make artifacts` the
//! binary is self-contained.
//!
//! See `docs/ARCHITECTURE.md` for the system map — the three runtime
//! layers (coordinator / serve / numerics+graph), the life of a served
//! request (stage → WFQ grant → batch → infer), and the invariants the
//! test suites pin (bitwise equivalence, zero-alloc steady state,
//! slot-leak hard-fail) — and `ROADMAP.md` for the open items.

pub mod baselines;
pub mod cli;
pub mod coordinator;
pub mod datasets;
pub mod energy;
pub mod error;
pub mod fpga;
pub mod graph;
pub mod metrics;
pub mod models;
pub mod numerics;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod testutil;

pub use error::{Error, Result};
