//! Bench E3 — regenerates **Table IV** (per-snapshot latency, CPU vs GPU
//! vs FPGA, with speedups) and times each platform model; also reports
//! the *measured* pure-Rust CPU latency on this machine alongside the
//! analytic 6226R model (CPU-baseline substitution, docs/ARCHITECTURE.md).

use dgnn_booster::baselines::cpu;
use dgnn_booster::datasets::{BC_ALPHA, UCI};
use dgnn_booster::fpga::designs::{avg_latency_ms, AcceleratorConfig};
use dgnn_booster::metrics::bench_loop;
use dgnn_booster::models::{EvolveGcnParams, GcrnM2Params, ModelKind};
use dgnn_booster::report::tables::{snapshots, table4, ReportCtx};

fn main() {
    let ctx = ReportCtx::default();
    println!("{}", table4(&ctx).expect("table4"));

    // measured CPU baseline (pure-Rust mirror on this machine), serial
    // and through the 4-thread sparse engine (node-parallel CSR kernels)
    println!("Measured CPU baseline (this machine, pure-Rust mirror):");
    let eng4 = dgnn_booster::numerics::Engine::new(4);
    for p in [&BC_ALPHA, &UCI] {
        let mut snaps = snapshots(&ctx, p).expect("snaps");
        snaps.truncate(40);
        let ep = EvolveGcnParams::init(ctx.seed, Default::default());
        let (ms_e, _) = cpu::measure_evolvegcn(&snaps, &ep, ctx.seed);
        let gp = GcrnM2Params::init(ctx.seed, Default::default());
        let total_nodes = snaps
            .iter()
            .flat_map(|s| s.renumber.iter().map(|(_, r)| r as usize + 1))
            .max()
            .unwrap_or(1);
        let (ms_g, sum_serial) = cpu::measure_gcrn(&snaps, &gp, total_nodes, ctx.seed);
        let (ms_g4, sum_par) = cpu::measure_gcrn_with(&eng4, &snaps, &gp, total_nodes, ctx.seed);
        assert_eq!(sum_serial, sum_par, "parallel engine diverged from serial");
        println!(
            "  {:>9}: EvolveGCN {ms_e:.3} ms/snap, GCRN-M2 {ms_g:.3} ms/snap (x4 engine {ms_g4:.3})",
            p.name
        );
    }

    // timing of the FPGA simulator itself (it sits on the bench path)
    let snaps = snapshots(&ctx, &BC_ALPHA).expect("snaps");
    for model in [ModelKind::EvolveGcn, ModelKind::GcrnM2] {
        let cfg = AcceleratorConfig::paper_default(model);
        bench_loop(&format!("fpga sim full stream ({})", model.name()), 10, || {
            avg_latency_ms(&cfg, &snaps)
        });
    }
}
