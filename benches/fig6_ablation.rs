//! Bench E7 — regenerates **Fig. 6** (ablation: Baseline / Pipeline-O1 /
//! Pipeline-O2 speedups over the GPU and non-optimised FPGA baselines).

use dgnn_booster::metrics::bench_loop;
use dgnn_booster::report::tables::{fig6, ReportCtx};

fn main() {
    let ctx = ReportCtx::default();
    println!("{}", fig6(&ctx).expect("fig6"));
    bench_loop("fig6 full regeneration", 3, || fig6(&ctx).unwrap());
}
