//! Bench E4 — regenerates **Table V** (total energy incl. idle, J per
//! 100 snapshots).

use dgnn_booster::metrics::bench_loop;
use dgnn_booster::report::tables::{table5, ReportCtx};

fn main() {
    let ctx = ReportCtx::default();
    println!("{}", table5(&ctx).expect("table5"));
    bench_loop("table5 full regeneration", 3, || table5(&ctx).unwrap());
}
