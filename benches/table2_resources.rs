//! Bench E1 — regenerates **Table II** (ZCU102 resource utilisation) and
//! times the resource-model evaluation.

use dgnn_booster::fpga::designs::AcceleratorConfig;
use dgnn_booster::fpga::resources;
use dgnn_booster::metrics::bench_loop;
use dgnn_booster::models::ModelKind;
use dgnn_booster::report::tables::{table2, ReportCtx};

fn main() {
    let ctx = ReportCtx::default();
    println!("{}", table2(&ctx).expect("table2"));
    bench_loop("resources::estimate(EvolveGCN)", 1000, || {
        resources::estimate(
            &AcceleratorConfig::paper_default(ModelKind::EvolveGcn),
            608,
            1728,
        )
    });
    bench_loop("resources::estimate(GCRN-M2)", 1000, || {
        resources::estimate(
            &AcceleratorConfig::paper_default(ModelKind::GcrnM2),
            608,
            1728,
        )
    });
}
