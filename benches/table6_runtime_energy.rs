//! Bench E5 — regenerates **Table VI** (runtime energy, J per 100
//! snapshots) — the paper's headline 100×/1000× efficiency claim.

use dgnn_booster::metrics::bench_loop;
use dgnn_booster::report::tables::{table6, ReportCtx};

fn main() {
    let ctx = ReportCtx::default();
    println!("{}", table6(&ctx).expect("table6"));
    bench_loop("table6 full regeneration", 3, || table6(&ctx).unwrap());
}
