//! Bench E2 — regenerates **Table III** (dataset statistics) and times
//! generation + preprocessing of both streams.

use dgnn_booster::coordinator::preprocess::preprocess_stream;
use dgnn_booster::datasets::{synth, BC_ALPHA, UCI};
use dgnn_booster::metrics::bench_loop;
use dgnn_booster::report::tables::{table3, ReportCtx};

fn main() {
    let ctx = ReportCtx::default();
    println!("{}", table3(&ctx).expect("table3"));
    for p in [&BC_ALPHA, &UCI] {
        let stream = synth::generate(p, ctx.seed);
        bench_loop(&format!("synth::generate({})", p.name), 5, || {
            synth::generate(p, ctx.seed)
        });
        bench_loop(&format!("preprocess_stream({})", p.name), 5, || {
            preprocess_stream(&stream, p.splitter_secs).unwrap()
        });
    }
}
