//! Served-traffic benchmark: sweep tenant-stream count × §VI delta
//! on/off through `serve::Scheduler` (mirror GCRN-M2 sessions over one
//! shared sparse engine and one recycled staging pool), a **streams ×
//! batch** sweep (all tenants sharing one model, cross-stream batched
//! projection on vs off — batch occupancy and fused-call counts land
//! in the JSON), plus two dynamic points — a **weighted** run (weights
//! 1:2:4 under a tight slot pool, with the per-tenant fairness summary)
//! and a **churn** run (one tenant admitted mid-run, one drained) —
//! and record per-request end-to-end latency tails + throughput per
//! sweep point.  A **model sweep** pairs the TGAT temporal-attention
//! mirror against GCRN-M2 on identical rosters (batch on, so both
//! families' projection fusion shows up), and a **konect-vs-synth**
//! pair serves the vendored KONECT slice loaded from `data/konect/`
//! next to the synthetic stream generated from the same profile.  Edit-stream serving gets its own sweeps: an
//! **edits-vs-snapshot** pair (the same per-step snapshots staged via
//! the CSR patch path vs force-restaged from scratch through
//! [`FullRestageSession`]), a **pool-vs-thread-per-tenant** pair
//! (`Scheduler::with_stage_pool`), a 64-tenant/4-worker density point
//! that asserts the thread-count probe, and a lane-backend marker row
//! whose name records `cfg!(feature = "simd")` so the `--features simd`
//! bench run lands distinguishable rows (`simd_default` extra in the
//! JSON).
//!
//! Writes `BENCH_serve.json` (schema in README.md § serve) so the
//! serving-perf trajectory is machine-tracked across PRs, like
//! `BENCH_hotpath.json` / `BENCH_kernels.json`.
//!
//! `cargo bench --bench serve_traffic` — full sweep (1/2/4 streams).
//! `cargo bench --bench serve_traffic -- --smoke` — 2 streams, tiny
//! snapshot budget (the CI gate).

use dgnn_booster::datasets::{self, synth, BC_ALPHA, KONECT_FORUM};
use dgnn_booster::graph::CooStream;
use dgnn_booster::models::{Dims, ModelKind};
use dgnn_booster::numerics::Engine;
use dgnn_booster::serve::{
    fairness_of, write_serve_json, BatchStats, Command, DgnnSession, FaultPlan, FaultPoint,
    FaultSpec, FullRestageSession, HealthStats, NetClient, NetEvent, NetServer, NetServerConfig,
    Scheduler, ServeEvent, ServePolicy, ServeRecorder, ServeRow, SessionConfig, ShardConfig,
    StreamOutcome, StreamSource, TenantRequest, TenantSpec,
};
use dgnn_booster::testutil::Pcg32;
use std::sync::Arc;

/// Shared-engine worker threads for every sweep point.
const THREADS: usize = 2;

fn session_cfg(stream: &CooStream, seed: u64, max_nodes: usize, delta: bool, engine: &Arc<Engine>) -> SessionConfig {
    SessionConfig {
        dims: Dims::default(),
        seed,
        total_nodes: stream.num_nodes as usize,
        max_nodes,
        delta,
        engine: Arc::clone(engine),
    }
}

/// Session config for an edit-stream tenant: the node universe is the
/// stream's fixed identity-renumbered `total_nodes`, not a COO stream.
fn edit_cfg(total_nodes: usize, seed: u64, max_nodes: usize, engine: &Arc<Engine>) -> SessionConfig {
    SessionConfig {
        dims: Dims::default(),
        seed,
        total_nodes,
        max_nodes,
        delta: false,
        engine: Arc::clone(engine),
    }
}

/// One profile-shaped synthetic edit stream per tenant (fixed node
/// universe, exact per-step deltas), deterministic in `seed`.
fn edit_streams(n_tenants: usize, seed: u64, steps: usize) -> Vec<Arc<Vec<synth::EditStep>>> {
    (0..n_tenants)
        .map(|i| {
            let mut rng = Pcg32::seeded(seed + i as u64);
            Arc::new(synth::edit_stream(
                &mut rng,
                BC_ALPHA.avg_nodes.max(1),
                BC_ALPHA.avg_edges,
                steps,
                0.15,
            ))
        })
        .collect()
}

/// Fold one run's outcomes into a row, optionally with fairness,
/// batching and health counters.
#[allow(clippy::too_many_arguments)]
fn row_from(
    name: String,
    streams: usize,
    delta: bool,
    edits: bool,
    stage_pool: usize,
    wall: f64,
    outcomes: &[StreamOutcome],
    with_fairness: bool,
    batch: Option<BatchStats>,
    health: Option<HealthStats>,
) -> ServeRow {
    let mut rec = ServeRecorder::new(65536);
    for o in outcomes {
        for st in &o.steps {
            rec.record_ms(st.e2e_ms);
        }
    }
    let fairness = with_fairness.then(|| fairness_of(outcomes));
    ServeRow {
        name,
        streams,
        delta,
        edits,
        threads: THREADS,
        stage_pool,
        summary: rec.summary(wall),
        fairness,
        batch,
        health,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let model = ModelKind::GcrnM2;
    let dims = Dims::default();
    let (stream_counts, limit): (&[usize], usize) =
        if smoke { (&[2], 8) } else { (&[1, 2, 4], usize::MAX) };

    let mut rows: Vec<ServeRow> = Vec::new();

    // static sweep: streams × delta, equal weights (the legacy path)
    for &k in stream_counts {
        for delta in [false, true] {
            let sources: Vec<StreamSource> = (0..k)
                .map(|i| StreamSource {
                    name: format!("stream-{i}"),
                    stream: synth::generate(&BC_ALPHA, 42 + i as u64),
                    splitter_secs: BC_ALPHA.splitter_secs,
                })
                .collect();
            let engine = Arc::new(Engine::new(THREADS));
            let manifest = Scheduler::manifest_for(&sources, dims);
            let sessions: Vec<Box<dyn DgnnSession>> = sources
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    model.build_session(&session_cfg(
                        &s.stream,
                        42 + i as u64,
                        manifest.max_nodes,
                        delta,
                        &engine,
                    ))
                })
                .collect();
            let sched = Scheduler::new(engine, (2 * k).clamp(2, 16));
            let t0 = std::time::Instant::now();
            let outcomes = sched
                .run(&manifest, &sources, sessions, limit, |_, _, _, _| Ok(()))
                .expect("serve sweep point");
            let wall = t0.elapsed().as_secs_f64();
            let name = format!(
                "serve {} streams={k} delta={}",
                model.name(),
                if delta { "on" } else { "off" }
            );
            let row = row_from(name, k, delta, false, 0, wall, &outcomes, false, None, None);
            println!("bench {:<44} {}", row.name, row.summary.line());
            rows.push(row);
        }
    }

    // streams × batch sweep: every tenant serves the SAME model (shared
    // parameter seed — the one-model-many-streams production shape), so
    // same-shape projections carry identical weights and the batched
    // runs report real cross-tenant fusion.  The batch-off twins make
    // the pair a like-for-like comparison.
    for &k in stream_counts {
        for batch in [false, true] {
            let streams: Vec<Arc<CooStream>> = (0..k)
                .map(|i| Arc::new(synth::generate(&BC_ALPHA, 342 + i as u64)))
                .collect();
            let engine = Arc::new(Engine::new(THREADS));
            let manifest = Scheduler::manifest_for_streams(
                streams.iter().map(|s| (s.as_ref(), BC_ALPHA.splitter_secs)),
                dims,
            );
            let tenants: Vec<TenantSpec> = streams
                .iter()
                .enumerate()
                .map(|(i, stream)| {
                    // one shared seed: one model across every tenant
                    let session = model.build_session(&session_cfg(
                        stream,
                        4242,
                        manifest.max_nodes,
                        true,
                        &engine,
                    ));
                    TenantSpec::new(
                        &format!("shared-{i}"),
                        Arc::clone(stream),
                        BC_ALPHA.splitter_secs,
                        1,
                        session,
                    )
                    .with_limit(limit)
                })
                .collect();
            let sched = Scheduler::new(engine, (2 * k).clamp(2, 16)).with_batching(batch);
            let t0 = std::time::Instant::now();
            let report = sched
                .serve_report(&manifest, tenants, |_| Vec::new(), |_, _, _, _| Ok(()))
                .expect("batch sweep point");
            let (outcomes, stats) = (report.outcomes, report.batch);
            let wall = t0.elapsed().as_secs_f64();
            let name = format!(
                "serve shared {} streams={k} batch={}",
                model.name(),
                if batch { "on" } else { "off" }
            );
            let row = row_from(
                name,
                k,
                true,
                false,
                0,
                wall,
                &outcomes,
                false,
                batch.then_some(stats),
                None,
            );
            if batch {
                println!(
                    "bench {:<44} {} occupancy={:.2} rows/call={:.0}",
                    row.name,
                    row.summary.line(),
                    stats.occupancy(),
                    stats.rows_per_call()
                );
            } else {
                println!("bench {:<44} {}", row.name, row.summary.line());
            }
            rows.push(row);
        }
    }

    // model sweep: identical tenant rosters served by the TGAT
    // temporal-attention mirror vs the GCRN-M2 recurrent mirror, batch
    // on with one shared parameter seed — the pair prices temporal
    // attention (time-encoded softmax over in-neighbors) against the
    // GRU recurrence at serve scale, and both families' cross-tenant
    // projection fusion lands in the occupancy counters
    for &k in stream_counts {
        for kind in [ModelKind::GcrnM2, ModelKind::Tgat] {
            let streams: Vec<Arc<CooStream>> = (0..k)
                .map(|i| Arc::new(synth::generate(&BC_ALPHA, 1042 + i as u64)))
                .collect();
            let engine = Arc::new(Engine::new(THREADS));
            let manifest = Scheduler::manifest_for_streams(
                streams.iter().map(|s| (s.as_ref(), BC_ALPHA.splitter_secs)),
                dims,
            );
            let tenants: Vec<TenantSpec> = streams
                .iter()
                .enumerate()
                .map(|(i, stream)| {
                    let session = kind.build_session(&session_cfg(
                        stream,
                        4242,
                        manifest.max_nodes,
                        true,
                        &engine,
                    ));
                    TenantSpec::new(
                        &format!("mk-{i}"),
                        Arc::clone(stream),
                        BC_ALPHA.splitter_secs,
                        1,
                        session,
                    )
                    .with_limit(limit)
                })
                .collect();
            let sched = Scheduler::new(engine, (2 * k).clamp(2, 16)).with_batching(true);
            let t0 = std::time::Instant::now();
            let report = sched
                .serve_report(&manifest, tenants, |_| Vec::new(), |_, _, _, _| Ok(()))
                .expect("model sweep point");
            let wall = t0.elapsed().as_secs_f64();
            let stats = report.batch;
            let name = format!("serve model {} streams={k} batch=on", kind.name());
            let row = row_from(
                name,
                k,
                true,
                false,
                0,
                wall,
                &report.outcomes,
                false,
                Some(stats),
                None,
            );
            println!(
                "bench {:<44} {} occupancy={:.2}",
                row.name,
                row.summary.line(),
                stats.occupancy()
            );
            rows.push(row);
        }
    }

    // konect-vs-synth pair: tenant 0 serves the vendored KONECT slice
    // loaded from data/konect/ (the real file-parsing path end to end),
    // its twin serves the synthetic stream generated from the same
    // profile — real-trace vs generator traffic shape at identical
    // Table-III-style stats.  Tenant 1 is synthetic in both runs.
    for vendored in [false, true] {
        let k = 2usize;
        let streams: Vec<Arc<CooStream>> = (0..k)
            .map(|i| {
                if i == 0 && vendored {
                    Arc::new(
                        datasets::load_or_generate(&KONECT_FORUM, "data", 7)
                            .expect("vendored konect slice under data/"),
                    )
                } else {
                    Arc::new(synth::generate(&KONECT_FORUM, 1142 + i as u64))
                }
            })
            .collect();
        let engine = Arc::new(Engine::new(THREADS));
        let manifest = Scheduler::manifest_for_streams(
            streams.iter().map(|s| (s.as_ref(), KONECT_FORUM.splitter_secs)),
            dims,
        );
        let tenants: Vec<TenantSpec> = streams
            .iter()
            .enumerate()
            .map(|(i, stream)| {
                let session = model.build_session(&session_cfg(
                    stream,
                    1142 + i as u64,
                    manifest.max_nodes,
                    true,
                    &engine,
                ));
                TenantSpec::new(
                    &format!("kn-{i}"),
                    Arc::clone(stream),
                    KONECT_FORUM.splitter_secs,
                    1,
                    session,
                )
                .with_limit(limit)
            })
            .collect();
        let sched = Scheduler::new(engine, 4);
        let t0 = std::time::Instant::now();
        let report = sched
            .serve_report(&manifest, tenants, |_| Vec::new(), |_, _, _, _| Ok(()))
            .expect("konect sweep point");
        let wall = t0.elapsed().as_secs_f64();
        let name = format!(
            "serve konect {} streams={k}",
            if vendored { "vendored" } else { "synth" }
        );
        let row = row_from(name, k, true, false, 0, wall, &report.outcomes, false, None, None);
        println!("bench {:<44} {}", row.name, row.summary.line());
        rows.push(row);
    }

    // weighted point: 3 tenants at 1:2:4 over a tight 2-slot pool —
    // the fairness summary lands in the JSON
    {
        let streams: Vec<Arc<CooStream>> = (0..3)
            .map(|i| Arc::new(synth::generate(&BC_ALPHA, 142 + i as u64)))
            .collect();
        let weights = [1u32, 2, 4];
        let engine = Arc::new(Engine::new(THREADS));
        let manifest = Scheduler::manifest_for_streams(
            streams.iter().map(|s| (s.as_ref(), BC_ALPHA.splitter_secs)),
            dims,
        );
        let tenants: Vec<TenantSpec> = streams
            .iter()
            .enumerate()
            .map(|(i, stream)| {
                let session = model.build_session(&session_cfg(
                    stream,
                    142 + i as u64,
                    manifest.max_nodes,
                    true,
                    &engine,
                ));
                TenantSpec::new(
                    &format!("w{}", weights[i]),
                    Arc::clone(stream),
                    BC_ALPHA.splitter_secs,
                    weights[i],
                    session,
                )
                .with_limit(limit)
            })
            .collect();
        let sched = Scheduler::new(engine, 2);
        // stop mid-saturation: if every tenant ran its stream dry the
        // served counts would mirror the (equal) stream lengths and the
        // jain index would measure nothing about the scheduler
        let stop_at: u64 = if smoke { 10 } else { 140 };
        let mut stopped = false;
        let t0 = std::time::Instant::now();
        let outcomes = sched
            .serve(
                &manifest,
                tenants,
                |ev| {
                    if let ServeEvent::Step { served_total, .. } = ev {
                        if !stopped && served_total >= stop_at {
                            stopped = true;
                            return vec![Command::Stop];
                        }
                    }
                    Vec::new()
                },
                |_, _, _, _| Ok(()),
            )
            .expect("weighted sweep point");
        let wall = t0.elapsed().as_secs_f64();
        let row = row_from(
            "serve weighted 1:2:4".into(),
            3,
            true,
            false,
            0,
            wall,
            &outcomes,
            true,
            None,
            None,
        );
        let jain = row.fairness.as_ref().map(|f| f.jain).unwrap_or(1.0);
        println!("bench {:<44} {} jain={jain:.3}", row.name, row.summary.line());
        rows.push(row);
    }

    // churn point: start with 2 tenants, admit a third mid-run, then
    // drain tenant 1 — exercises the admission/removal machinery at
    // bench scale
    {
        let streams: Vec<Arc<CooStream>> = (0..3)
            .map(|i| Arc::new(synth::generate(&BC_ALPHA, 242 + i as u64)))
            .collect();
        let engine = Arc::new(Engine::new(THREADS));
        let manifest = Scheduler::manifest_for_streams(
            streams.iter().map(|s| (s.as_ref(), BC_ALPHA.splitter_secs)),
            dims,
        );
        let tenants: Vec<TenantSpec> = streams[..2]
            .iter()
            .enumerate()
            .map(|(i, stream)| {
                let session = model.build_session(&session_cfg(
                    stream,
                    242 + i as u64,
                    manifest.max_nodes,
                    true,
                    &engine,
                ));
                TenantSpec::new(
                    &format!("t{i}"),
                    Arc::clone(stream),
                    BC_ALPHA.splitter_secs,
                    1,
                    session,
                )
                .with_limit(limit)
            })
            .collect();
        let sched = Scheduler::new(Arc::clone(&engine), 4);
        let mut late = Some(Arc::clone(&streams[2]));
        let mut removed = false;
        let admit_at = if smoke { 4 } else { 40 };
        let t0 = std::time::Instant::now();
        let outcomes = sched
            .serve(
                &manifest,
                tenants,
                |ev| {
                    let ServeEvent::Step { served_total, .. } = ev else {
                        return Vec::new();
                    };
                    let mut cmds = Vec::new();
                    if served_total >= admit_at {
                        if let Some(stream) = late.take() {
                            let session = model.build_session(&session_cfg(
                                &stream,
                                242 + 2,
                                manifest.max_nodes,
                                true,
                                &engine,
                            ));
                            cmds.push(Command::Admit(
                                TenantSpec::new(
                                    "late",
                                    stream,
                                    BC_ALPHA.splitter_secs,
                                    2,
                                    session,
                                )
                                .with_limit(limit),
                            ));
                        }
                    }
                    if !removed && served_total >= 2 * admit_at {
                        removed = true;
                        cmds.push(Command::Remove(1));
                    }
                    cmds
                },
                |_, _, _, _| Ok(()),
            )
            .expect("churn sweep point");
        let wall = t0.elapsed().as_secs_f64();
        let row = row_from(
            "serve churn admit+drain".into(),
            3,
            true,
            false,
            0,
            wall,
            &outcomes,
            true,
            None,
            None,
        );
        println!("bench {:<44} {}", row.name, row.summary.line());
        rows.push(row);
    }

    // overload point A: sub-microsecond deadlines under contention with
    // stale-window shedding disabled — every served window misses its
    // target, so the JSON carries a pure deadline-miss signal
    {
        let streams: Vec<Arc<CooStream>> = (0..3)
            .map(|i| Arc::new(synth::generate(&BC_ALPHA, 442 + i as u64)))
            .collect();
        let engine = Arc::new(Engine::new(THREADS));
        let manifest = Scheduler::manifest_for_streams(
            streams.iter().map(|s| (s.as_ref(), BC_ALPHA.splitter_secs)),
            dims,
        );
        let dl_limit = if smoke { 6 } else { 24 };
        let tenants: Vec<TenantSpec> = streams
            .iter()
            .enumerate()
            .map(|(i, stream)| {
                let session = model.build_session(&session_cfg(
                    stream,
                    442 + i as u64,
                    manifest.max_nodes,
                    true,
                    &engine,
                ));
                TenantSpec::new(
                    &format!("dl{i}"),
                    Arc::clone(stream),
                    BC_ALPHA.splitter_secs,
                    1,
                    session,
                )
                .with_limit(dl_limit)
                .with_deadline_ms(0.001)
            })
            .collect();
        let sched = Scheduler::new(engine, 2).with_policy(ServePolicy {
            stale_factor: f64::INFINITY,
            ..Default::default()
        });
        let t0 = std::time::Instant::now();
        let report = sched
            .serve_report(&manifest, tenants, |_| Vec::new(), |_, _, _, _| Ok(()))
            .expect("deadline sweep point");
        let wall = t0.elapsed().as_secs_f64();
        let row = row_from(
            "serve overload deadline-miss".into(),
            3,
            true,
            false,
            0,
            wall,
            &report.outcomes,
            false,
            None,
            Some(report.health),
        );
        println!(
            "bench {:<44} {} misses={}",
            row.name,
            row.summary.line(),
            report.health.deadline_misses
        );
        rows.push(row);
    }

    // overload point B: the same impossible deadlines with shedding on
    // (default stale factor) plus one scripted transient stage fault —
    // queued windows go stale, consecutive sheds trip the per-tenant
    // breaker, and the retried fault lands nonzero retry counters
    {
        let streams: Vec<Arc<CooStream>> = (0..3)
            .map(|i| Arc::new(synth::generate(&BC_ALPHA, 542 + i as u64)))
            .collect();
        let engine = Arc::new(Engine::new(THREADS));
        let manifest = Scheduler::manifest_for_streams(
            streams.iter().map(|s| (s.as_ref(), BC_ALPHA.splitter_secs)),
            dims,
        );
        let dl_limit = if smoke { 6 } else { 24 };
        let tenants: Vec<TenantSpec> = streams
            .iter()
            .enumerate()
            .map(|(i, stream)| {
                let session = model.build_session(&session_cfg(
                    stream,
                    542 + i as u64,
                    manifest.max_nodes,
                    true,
                    &engine,
                ));
                TenantSpec::new(
                    &format!("sb{i}"),
                    Arc::clone(stream),
                    BC_ALPHA.splitter_secs,
                    1,
                    session,
                )
                .with_limit(dl_limit)
                .with_deadline_ms(0.001)
            })
            .collect();
        let plan = FaultPlan::new().with(FaultSpec {
            tenant: 0,
            point: FaultPoint::Stage,
            index: 0,
            transient: true,
            fires: 1,
        });
        let sched = Scheduler::new(engine, 2).with_faults(Arc::new(plan));
        let t0 = std::time::Instant::now();
        let report = sched
            .serve_report(&manifest, tenants, |_| Vec::new(), |_, _, _, _| Ok(()))
            .expect("shed sweep point");
        let wall = t0.elapsed().as_secs_f64();
        let h = report.health;
        let row = row_from(
            "serve overload shed+breaker".into(),
            3,
            true,
            false,
            0,
            wall,
            &report.outcomes,
            false,
            None,
            Some(h),
        );
        println!(
            "bench {:<44} {} shed={} breaker_trips={} retries={}",
            row.name,
            row.summary.line(),
            h.shed + h.deadline_shed,
            h.breaker_trips,
            h.retries
        );
        rows.push(row);
    }

    // edits-vs-snapshot sweep: the same per-step snapshots staged twice
    // — once through the CSR patch path (`TenantSpec::new_edits`) and
    // once force-restaged from scratch (`FullRestageSession` strips the
    // stage_edit override, so the trait default rebuilds every step) —
    // isolating what in-place patching is worth at serve scale
    let edit_len = if smoke { 8 } else { 48 };
    for &k in stream_counts {
        for patch in [false, true] {
            let steps = edit_streams(k, 642, edit_len);
            let engine = Arc::new(Engine::new(THREADS));
            let manifest =
                Scheduler::manifest_for_edits(steps.iter().map(|s| s.as_slice()), dims);
            let tenants: Vec<TenantSpec> = steps
                .iter()
                .enumerate()
                .map(|(i, st)| {
                    let mut session = model.build_session(&edit_cfg(
                        BC_ALPHA.avg_nodes.max(1),
                        642 + i as u64,
                        manifest.max_nodes,
                        &engine,
                    ));
                    if !patch {
                        session = FullRestageSession::new(session);
                    }
                    TenantSpec::new_edits(&format!("edit-{i}"), Arc::clone(st), 1, session)
                })
                .collect();
            let sched = Scheduler::new(engine, (2 * k).clamp(2, 16));
            let t0 = std::time::Instant::now();
            let report = sched
                .serve_report(&manifest, tenants, |_| Vec::new(), |_, _, _, _| Ok(()))
                .expect("edits sweep point");
            let wall = t0.elapsed().as_secs_f64();
            let (mut patched, mut seen) = (0usize, 0usize);
            for o in &report.outcomes {
                if let Some(d) = o.csr_delta {
                    patched += d.shared;
                    seen += d.seen;
                }
            }
            let name = format!(
                "serve edits {} streams={k} patch={}",
                model.name(),
                if patch { "on" } else { "off" }
            );
            let row =
                row_from(name, k, false, true, 0, wall, &report.outcomes, false, None, None);
            println!(
                "bench {:<44} {} patched={patched}/{seen}",
                row.name,
                row.summary.line()
            );
            rows.push(row);
        }
    }

    // pool-vs-thread-per-tenant pair: identical edit-stream tenant sets,
    // staged once thread-per-tenant (stage_pool=0) and once on a fixed
    // 4-worker work-stealing pool
    {
        let k = *stream_counts.last().unwrap();
        for pool in [0usize, 4] {
            let steps = edit_streams(k, 742, edit_len);
            let engine = Arc::new(Engine::new(THREADS));
            let manifest =
                Scheduler::manifest_for_edits(steps.iter().map(|s| s.as_slice()), dims);
            let tenants: Vec<TenantSpec> = steps
                .iter()
                .enumerate()
                .map(|(i, st)| {
                    let session = model.build_session(&edit_cfg(
                        BC_ALPHA.avg_nodes.max(1),
                        742 + i as u64,
                        manifest.max_nodes,
                        &engine,
                    ));
                    TenantSpec::new_edits(&format!("pool-{i}"), Arc::clone(st), 1, session)
                })
                .collect();
            let sched = Scheduler::new(engine, (2 * k).clamp(2, 16)).with_stage_pool(pool);
            let t0 = std::time::Instant::now();
            let report = sched
                .serve_report(&manifest, tenants, |_| Vec::new(), |_, _, _, _| Ok(()))
                .expect("pool sweep point");
            let wall = t0.elapsed().as_secs_f64();
            let name = format!("serve pool {} streams={k} stage_pool={pool}", model.name());
            let row =
                row_from(name, k, false, true, pool, wall, &report.outcomes, false, None, None);
            println!(
                "bench {:<44} {} stage_threads={}",
                row.name,
                row.summary.line(),
                report.stage_threads
            );
            rows.push(row);
        }
    }

    // tenant-density point: 64 edit-stream tenants multiplexed over a
    // 4-worker stage pool — idle/parked tenants cost zero threads, so
    // the probe must stay at pool size (+2 for collector/inference slack
    // in the acceptance bound), independent of tenant count
    {
        let tenant_n = 64;
        let pool = 4;
        let steps = edit_streams(tenant_n, 842, if smoke { 2 } else { 4 });
        let engine = Arc::new(Engine::new(THREADS));
        let manifest = Scheduler::manifest_for_edits(steps.iter().map(|s| s.as_slice()), dims);
        let tenants: Vec<TenantSpec> = steps
            .iter()
            .enumerate()
            .map(|(i, st)| {
                let session = model.build_session(&edit_cfg(
                    BC_ALPHA.avg_nodes.max(1),
                    842 + i as u64,
                    manifest.max_nodes,
                    &engine,
                ));
                TenantSpec::new_edits(&format!("hd-{i}"), Arc::clone(st), 1, session)
            })
            .collect();
        let sched = Scheduler::new(engine, 8).with_stage_pool(pool);
        let t0 = std::time::Instant::now();
        let report = sched
            .serve_report(&manifest, tenants, |_| Vec::new(), |_, _, _, _| Ok(()))
            .expect("density sweep point");
        let wall = t0.elapsed().as_secs_f64();
        assert!(
            report.stage_threads <= pool + 2,
            "stage pool leaked threads: {} spawned for {} tenants on a {pool}-worker pool",
            report.stage_threads,
            tenant_n
        );
        let row = row_from(
            format!("serve density streams={tenant_n} stage_pool={pool}"),
            tenant_n,
            false,
            true,
            pool,
            wall,
            &report.outcomes,
            false,
            None,
            None,
        );
        println!(
            "bench {:<44} {} stage_threads={}",
            row.name,
            row.summary.line(),
            report.stage_threads
        );
        rows.push(row);
    }

    // lane-backend marker point: the row name records whether the SIMD
    // feature was compiled in, so the tier1-simd bench run
    // (`cargo bench --features simd`) lands distinguishable rows next to
    // the scalar ones
    {
        let simd = if cfg!(feature = "simd") { "on" } else { "off" };
        let steps = edit_streams(1, 942, edit_len);
        let engine = Arc::new(Engine::new(THREADS));
        let manifest = Scheduler::manifest_for_edits(steps.iter().map(|s| s.as_slice()), dims);
        let session = model.build_session(&edit_cfg(
            BC_ALPHA.avg_nodes.max(1),
            942,
            manifest.max_nodes,
            &engine,
        ));
        let tenants =
            vec![TenantSpec::new_edits("simd-0", Arc::clone(&steps[0]), 1, session)];
        let sched = Scheduler::new(engine, 2).with_stage_pool(2);
        let t0 = std::time::Instant::now();
        let report = sched
            .serve_report(&manifest, tenants, |_| Vec::new(), |_, _, _, _| Ok(()))
            .expect("simd sweep point");
        let wall = t0.elapsed().as_secs_f64();
        let row = row_from(
            format!("serve edits simd={simd} stage_pool=2"),
            1,
            false,
            true,
            2,
            wall,
            &report.outcomes,
            false,
            None,
            None,
        );
        println!("bench {:<44} {}", row.name, row.summary.line());
        rows.push(row);
    }

    // network load generator: open-loop arrivals against a real TCP
    // frontend (2 scheduler shards behind the wire protocol) — each
    // "request" admits a short-lived tenant over the socket, streams
    // its edges, and is complete when its Done frame lands back.
    // Arrivals are scheduled at the target rate regardless of
    // completions (open-loop, so queueing delay shows up in the tail
    // instead of throttling the generator); one latency-vs-QPS row per
    // target rate.
    {
        let shards = 2;
        let stage_pool = 2;
        let qps_targets: &[f64] = if smoke { &[4.0, 8.0, 16.0] } else { &[2.0, 8.0, 32.0] };
        let n_requests: usize = if smoke { 6 } else { 24 };
        let req_limit: u64 = if smoke { 2 } else { 4 };
        // small per-request stream: a prefix of a profile-shaped one,
        // so each request stages/serves only a handful of windows
        let base = synth::generate(&BC_ALPHA, 777);
        let edges: Vec<_> = base.edges.iter().take(1200).copied().collect();
        let small = CooStream::from_edges("netload", edges.clone()).expect("netload stream");

        for &qps in qps_targets {
            let manifest = Scheduler::manifest_for_streams(
                [(&small, BC_ALPHA.splitter_secs)],
                dims,
            );
            let server = NetServer::bind(
                "127.0.0.1:0",
                NetServerConfig {
                    shards,
                    shard: ShardConfig {
                        engine_threads: THREADS,
                        slots: 4,
                        stage_pool,
                        batch: false,
                        delta: true,
                        dims,
                    },
                    max_nodes: manifest.max_nodes,
                    max_edges: manifest.max_edges,
                },
            )
            .expect("bind netload server");
            let addr = server.local_addr().expect("netload addr");
            let server_thread = std::thread::spawn(move || server.run());

            let mut client = NetClient::connect(addr).expect("netload connect");
            let mut reader = client.try_clone().expect("netload reader clone");
            let collector = std::thread::spawn(move || {
                let mut done_at = std::collections::HashMap::new();
                while done_at.len() < n_requests {
                    match reader.next_event().expect("netload event") {
                        NetEvent::Step { .. } => {}
                        NetEvent::Done { token, .. } => {
                            done_at.insert(token, std::time::Instant::now());
                        }
                        NetEvent::Error { token, msg } => {
                            panic!("netload server error (token {token}): {msg}")
                        }
                    }
                }
                done_at
            });

            let start = std::time::Instant::now();
            let mut issued = Vec::with_capacity(n_requests);
            for k in 0..n_requests {
                let due = start + std::time::Duration::from_secs_f64(k as f64 / qps);
                let now = std::time::Instant::now();
                if due > now {
                    std::thread::sleep(due - now);
                }
                let token = k as u32;
                issued.push(std::time::Instant::now());
                client
                    .admit(&TenantRequest {
                        token,
                        name: format!("req-{k}"),
                        model,
                        seed: 777,
                        weight: 1,
                        deadline_us: 0,
                    })
                    .expect("netload admit");
                client.push_edits(token, &edges).expect("netload edits");
                client
                    .infer(token, BC_ALPHA.splitter_secs, req_limit)
                    .expect("netload infer");
            }
            let done_at = collector.join().expect("netload collector");
            let wall = issued[0].elapsed().as_secs_f64();
            client.shutdown().expect("netload shutdown");
            server_thread
                .join()
                .expect("netload server join")
                .expect("netload server report");

            let mut rec = ServeRecorder::new(65536);
            for (k, t0) in issued.iter().enumerate() {
                let t1 = done_at[&(k as u32)];
                rec.record_ms(t1.duration_since(*t0).as_secs_f64() * 1e3);
            }
            let row = ServeRow {
                name: format!("netload qps={qps:.0} shards={shards}"),
                streams: n_requests,
                delta: true,
                edits: false,
                threads: THREADS,
                stage_pool,
                summary: rec.summary(wall),
                fairness: None,
                batch: None,
                health: None,
            };
            println!("bench {:<44} {}", row.name, row.summary.line());
            rows.push(row);
        }
    }

    write_serve_json(
        "BENCH_serve.json",
        &rows,
        &[
            ("smoke", if smoke { 1.0 } else { 0.0 }),
            ("threads", THREADS as f64),
            ("streams_max", *stream_counts.last().unwrap() as f64),
            ("simd_default", if cfg!(feature = "simd") { 1.0 } else { 0.0 }),
        ],
    )
    .expect("write BENCH_serve.json");
    println!("wrote BENCH_serve.json ({} sweep points)", rows.len());
}
