//! Served-traffic benchmark — closes the ROADMAP item "wire `--delta`
//! into a served-traffic benchmark once a server frontend exists":
//! sweep tenant-stream count × §VI delta on/off through
//! `serve::Scheduler` (mirror GCRN-M2 sessions over one shared sparse
//! engine and one recycled staging pool) and record per-request
//! end-to-end latency tails + throughput per sweep point.
//!
//! Writes `BENCH_serve.json` (schema in README.md § serve) so the
//! serving-perf trajectory is machine-tracked across PRs, like
//! `BENCH_hotpath.json` / `BENCH_kernels.json`.
//!
//! `cargo bench --bench serve_traffic` — full sweep (1/2/4 streams).
//! `cargo bench --bench serve_traffic -- --smoke` — 2 streams, tiny
//! snapshot budget (the CI gate).

use dgnn_booster::datasets::{synth, BC_ALPHA};
use dgnn_booster::models::{Dims, ModelKind};
use dgnn_booster::numerics::Engine;
use dgnn_booster::serve::{
    write_serve_json, DgnnSession, Scheduler, ServeRecorder, ServeRow, SessionConfig,
    StreamSource,
};
use std::sync::Arc;

/// Shared-engine worker threads for every sweep point.
const THREADS: usize = 2;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let model = ModelKind::GcrnM2;
    let dims = Dims::default();
    let (stream_counts, limit): (&[usize], usize) =
        if smoke { (&[2], 8) } else { (&[1, 2, 4], usize::MAX) };

    let mut rows: Vec<ServeRow> = Vec::new();
    for &k in stream_counts {
        for delta in [false, true] {
            let sources: Vec<StreamSource> = (0..k)
                .map(|i| StreamSource {
                    name: format!("stream-{i}"),
                    stream: synth::generate(&BC_ALPHA, 42 + i as u64),
                    splitter_secs: BC_ALPHA.splitter_secs,
                })
                .collect();
            let engine = Arc::new(Engine::new(THREADS));
            let manifest = Scheduler::manifest_for(&sources, dims);
            let sessions: Vec<Box<dyn DgnnSession>> = sources
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    model.build_session(&SessionConfig {
                        dims,
                        seed: 42 + i as u64,
                        total_nodes: s.stream.num_nodes as usize,
                        max_nodes: manifest.max_nodes,
                        delta,
                        engine: Arc::clone(&engine),
                    })
                })
                .collect();
            let sched = Scheduler::new(engine, (2 * k).clamp(2, 16));
            let t0 = std::time::Instant::now();
            let outcomes = sched
                .run(&manifest, &sources, sessions, limit, |_, _, _, _| Ok(()))
                .expect("serve sweep point");
            let wall = t0.elapsed().as_secs_f64();

            let mut rec = ServeRecorder::new(65536);
            for o in &outcomes {
                for st in &o.steps {
                    rec.record_ms(st.e2e_ms);
                }
            }
            let summary = rec.summary(wall);
            let name = format!(
                "serve {} streams={k} delta={}",
                model.name(),
                if delta { "on" } else { "off" }
            );
            println!("bench {name:<44} {}", summary.line());
            rows.push(ServeRow { name, streams: k, delta, threads: THREADS, summary });
        }
    }

    write_serve_json(
        "BENCH_serve.json",
        &rows,
        &[
            ("smoke", if smoke { 1.0 } else { 0.0 }),
            ("threads", THREADS as f64),
            ("streams_max", *stream_counts.last().unwrap() as f64),
        ],
    )
    .expect("write BENCH_serve.json");
    println!("wrote BENCH_serve.json ({} sweep points)", rows.len());
}
