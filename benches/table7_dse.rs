//! Bench E6 — regenerates **Table VII** (DSP allocation + module
//! latencies) and times the DSE sweep.

use dgnn_booster::fpga::designs::AcceleratorConfig;
use dgnn_booster::fpga::dse;
use dgnn_booster::metrics::bench_loop;
use dgnn_booster::models::ModelKind;
use dgnn_booster::report::tables::{snapshots, table7, ReportCtx};
use dgnn_booster::datasets::BC_ALPHA;

fn main() {
    let ctx = ReportCtx::default();
    println!("{}", table7(&ctx).expect("table7"));
    let mut snaps = snapshots(&ctx, &BC_ALPHA).expect("snaps");
    snaps.truncate(24);
    for model in [ModelKind::EvolveGcn, ModelKind::GcrnM2] {
        let cfg = AcceleratorConfig::paper_default(model);
        bench_loop(&format!("dse::sweep 12 pts ({})", model.name()), 5, || {
            dse::sweep(&cfg, &snaps, cfg.total_dsp(), 12)
        });
    }
}
