//! Host message-passing kernel sweep: the COO edge-walk reference vs
//! the CSR engine (serial and node-parallel at several thread counts)
//! vs the fused aggregate-project kernel, over several synthetic graph
//! sizes.  The paper's V2 speedup comes from node-parallel message
//! passing (§V); this bench tracks how much of that the host-side
//! engine recovers on this machine.
//!
//! Writes `BENCH_kernels.json` (median + MAD per bench, same format as
//! `BENCH_hotpath.json`, plus the headline parallel-vs-COO speedup on
//! the largest graph) so the perf trajectory is machine-tracked across
//! PRs.  Before any timing, every CSR path is asserted bitwise-equal to
//! the COO reference.
//!
//! `cargo bench --bench kernels` — full sweep.
//! `cargo bench --bench kernels -- --smoke` — single-iteration CI gate.

use dgnn_booster::datasets::synth::random_snapshot;
use dgnn_booster::graph::SnapshotCsr;
use dgnn_booster::metrics::{bench_loop_record, write_bench_json, BenchRecord};
use dgnn_booster::numerics::{self, Engine, Mat};
use dgnn_booster::testutil::Pcg32;

/// (nodes, avg degree, feature dim); the last entry is the "largest
/// synthetic graph" the headline speedup is measured on.
const SIZES: [(usize, usize, usize); 3] = [(256, 8, 32), (1024, 16, 32), (4096, 16, 64)];
const THREADS: [usize; 3] = [1, 2, 4];

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut rng = Pcg32::seeded(42);
    let mut records: Vec<BenchRecord> = Vec::new();
    // headline numbers, taken on the largest size: the COO serial path
    // as shipped (allocating `numerics::aggregate`) and the alloc-free
    // COO walk, so the CSR/parallelism win is separable from the
    // allocation-removal win
    let mut coo_largest = 0.0f64;
    let mut coo_into_largest = 0.0f64;
    let mut csr4_largest = 0.0f64;
    let (n_big, _, _) = SIZES[SIZES.len() - 1];

    for (n, deg, d) in SIZES {
        let e = n * deg;
        let snap = random_snapshot(&mut rng, n, e);
        let csr = SnapshotCsr::from_snapshot(&snap);
        let x = Mat::from_vec(n, d, rng.normal_vec(n * d, 1.0));
        let w = Mat::from_vec(d, d, rng.normal_vec(d * d, 0.5));
        let serial = Engine::serial();
        // iteration budget scaled so each record costs roughly the same
        // wall time; --smoke collapses to one iteration per record
        let iters = if smoke { 1 } else { (40_000_000 / (e * d)).clamp(12, 200) };

        // --- bitwise gate before any timing -------------------------
        let reference = numerics::aggregate(&snap, &x);
        for t in THREADS {
            let eng = Engine::new(t);
            let got = eng.aggregate(&csr, &snap.selfcoef, &x);
            assert_eq!(
                got.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                reference.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "CSR t={t} diverged from COO reference at n={n}"
            );
        }

        // --- COO serial path (the reference walk, fresh output) -----
        let coo = bench_loop_record(&format!("aggregate coo n={n} deg={deg} d={d}"), iters, || {
            numerics::aggregate(&snap, &x).data[0]
        });
        // allocation-free COO variant, for the alloc-vs-kernel split
        let mut out = Mat::zeros(n, d);
        let coo_into = bench_loop_record(
            &format!("aggregate coo-into n={n} deg={deg} d={d}"),
            iters,
            || {
                numerics::aggregate_into(&snap, &x, &mut out);
                out.data[0]
            },
        );

        // --- CSR engine at each thread count ------------------------
        for t in THREADS {
            let eng = Engine::new(t);
            let rec = bench_loop_record(
                &format!("aggregate csr t={t} n={n} deg={deg} d={d}"),
                iters,
                || {
                    eng.aggregate_into(&csr, &snap.selfcoef, &x, &mut out);
                    out.data[0]
                },
            );
            if n == n_big && t == *THREADS.last().unwrap() {
                coo_largest = coo.median_s;
                coo_into_largest = coo_into.median_s;
                csr4_largest = rec.median_s;
            }
            records.push(rec);
        }

        // --- fused vs two-step GCN projection (serial) --------------
        let mut proj = Mat::zeros(n, d);
        records.push(bench_loop_record(
            &format!("agg+matmul two-step n={n} deg={deg} d={d}"),
            iters,
            || {
                serial.aggregate_into(&csr, &snap.selfcoef, &x, &mut out);
                serial.matmul_into(&out, &w, &mut proj);
                proj.data[0]
            },
        ));
        records.push(bench_loop_record(
            &format!("agg+matmul fused n={n} deg={deg} d={d}"),
            iters,
            || {
                serial.aggregate_matmul_into(&csr, &snap.selfcoef, &x, &w, &mut proj);
                proj.data[0]
            },
        ));
        records.push(coo);
        records.push(coo_into);
    }

    let speedup = if csr4_largest > 0.0 { coo_largest / csr4_largest } else { 0.0 };
    let speedup_into =
        if csr4_largest > 0.0 { coo_into_largest / csr4_largest } else { 0.0 };
    write_bench_json(
        "BENCH_kernels.json",
        &records,
        &[
            ("speedup_parallel_csr_vs_coo_largest", speedup),
            ("speedup_parallel_csr_vs_coo_into_largest", speedup_into),
            ("threads_max", *THREADS.last().unwrap() as f64),
            ("largest_nodes", n_big as f64),
            ("smoke", if smoke { 1.0 } else { 0.0 }),
        ],
    )
    .expect("write BENCH_kernels.json");
    println!(
        "wrote BENCH_kernels.json (parallel-CSR vs COO on n={n_big}: {speedup:.2}x \
         vs the shipped serial path, {speedup_into:.2}x vs the alloc-free walk, \
         at {} threads)",
        THREADS.last().unwrap()
    );
}
