//! Host message-passing kernel sweep: the COO edge-walk reference vs
//! the CSR engine (serial and node-parallel at several thread counts)
//! vs the fused aggregate-project kernel, over several synthetic graph
//! sizes.  The paper's V2 speedup comes from node-parallel message
//! passing (§V); this bench tracks how much of that the host-side
//! engine recovers on this machine.
//!
//! Writes `BENCH_kernels.json` (median + MAD per bench, same format as
//! `BENCH_hotpath.json`, plus the headline parallel-vs-COO speedup on
//! the largest graph) so the perf trajectory is machine-tracked across
//! PRs.  Before any timing, every CSR path is asserted bitwise-equal to
//! the COO reference.
//!
//! `cargo bench --bench kernels` — full sweep.
//! `cargo bench --bench kernels -- --smoke` — single-iteration CI gate.

use dgnn_booster::datasets::synth::{edit_stream, random_snapshot};
use dgnn_booster::graph::{CsrRebuild, EdgeDelta, Snapshot, SnapshotCsr, DELTA_CHURN_ALL};
use dgnn_booster::metrics::{bench_loop_record, write_bench_json, BenchRecord};
use dgnn_booster::numerics::{self, lstm_gate_slices_into, Engine, Kernels, Mat};
use dgnn_booster::testutil::Pcg32;

/// (nodes, avg degree, feature dim); the last entry is the "largest
/// synthetic graph" the headline speedup is measured on.
const SIZES: [(usize, usize, usize); 3] = [(256, 8, 32), (1024, 16, 32), (4096, 16, 64)];
const THREADS: [usize; 3] = [1, 2, 4];
/// Edit-stream churn fractions for the full-vs-delta rebuild sweep.
const CHURNS: [f64; 3] = [0.01, 0.05, 0.20];

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut rng = Pcg32::seeded(42);
    let mut records: Vec<BenchRecord> = Vec::new();
    // headline numbers, taken on the largest size: the COO serial path
    // as shipped (allocating `numerics::aggregate`) and the alloc-free
    // COO walk, so the CSR/parallelism win is separable from the
    // allocation-removal win
    let mut coo_largest = 0.0f64;
    let mut coo_into_largest = 0.0f64;
    let mut csr4_largest = 0.0f64;
    let (n_big, _, _) = SIZES[SIZES.len() - 1];

    for (n, deg, d) in SIZES {
        let e = n * deg;
        let snap = random_snapshot(&mut rng, n, e);
        let csr = SnapshotCsr::from_snapshot(&snap);
        let x = Mat::from_vec(n, d, rng.normal_vec(n * d, 1.0));
        let w = Mat::from_vec(d, d, rng.normal_vec(d * d, 0.5));
        let serial = Engine::serial();
        // iteration budget scaled so each record costs roughly the same
        // wall time; --smoke collapses to one iteration per record
        let iters = if smoke { 1 } else { (40_000_000 / (e * d)).clamp(12, 200) };

        // --- bitwise gate before any timing -------------------------
        let reference = numerics::aggregate(&snap, &x);
        for t in THREADS {
            let eng = Engine::new(t);
            let got = eng.aggregate(&csr, &snap.selfcoef, &x);
            assert_eq!(
                got.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                reference.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "CSR t={t} diverged from COO reference at n={n}"
            );
        }

        // --- COO serial path (the reference walk, fresh output) -----
        let coo = bench_loop_record(&format!("aggregate coo n={n} deg={deg} d={d}"), iters, || {
            numerics::aggregate(&snap, &x).data[0]
        });
        // allocation-free COO variant, for the alloc-vs-kernel split
        let mut out = Mat::zeros(n, d);
        let coo_into = bench_loop_record(
            &format!("aggregate coo-into n={n} deg={deg} d={d}"),
            iters,
            || {
                numerics::aggregate_into(&snap, &x, &mut out);
                out.data[0]
            },
        );

        // --- CSR engine at each thread count ------------------------
        for t in THREADS {
            let eng = Engine::new(t);
            let rec = bench_loop_record(
                &format!("aggregate csr t={t} n={n} deg={deg} d={d}"),
                iters,
                || {
                    eng.aggregate_into(&csr, &snap.selfcoef, &x, &mut out);
                    out.data[0]
                },
            );
            if n == n_big && t == *THREADS.last().unwrap() {
                coo_largest = coo.median_s;
                coo_into_largest = coo_into.median_s;
                csr4_largest = rec.median_s;
            }
            records.push(rec);
        }

        // --- fused vs two-step GCN projection (serial) --------------
        let mut proj = Mat::zeros(n, d);
        records.push(bench_loop_record(
            &format!("agg+matmul two-step n={n} deg={deg} d={d}"),
            iters,
            || {
                serial.aggregate_into(&csr, &snap.selfcoef, &x, &mut out);
                serial.matmul_into(&out, &w, &mut proj);
                proj.data[0]
            },
        ));
        records.push(bench_loop_record(
            &format!("agg+matmul fused n={n} deg={deg} d={d}"),
            iters,
            || {
                serial.aggregate_matmul_into(&csr, &snap.selfcoef, &x, &w, &mut proj);
                proj.data[0]
            },
        ));
        records.push(coo);
        records.push(coo_into);
    }

    // --- scalar vs lane kernels on the largest size -----------------
    // Both kernel sets are always compiled; `Engine::new_with` pins the
    // set per engine so one binary measures the pair side by side.  The
    // bitwise gate runs before any timing: the lane kernels must be
    // indistinguishable from the scalar oracle, not merely close.
    let (n, deg, d) = SIZES[SIZES.len() - 1];
    let e = n * deg;
    let snap = random_snapshot(&mut rng, n, e);
    let csr = SnapshotCsr::from_snapshot(&snap);
    let x = Mat::from_vec(n, d, rng.normal_vec(n * d, 1.0));
    let w = Mat::from_vec(d, d, rng.normal_vec(d * d, 0.5));
    let hdim = d;
    let px = rng.normal_vec(n * 4 * hdim, 0.5);
    let ph = rng.normal_vec(n * 4 * hdim, 0.5);
    let b = rng.normal_vec(4 * hdim, 0.5);
    let c = rng.normal_vec(n * hdim, 0.5);
    let mut out = Mat::zeros(n, d);
    let mut proj = Mat::zeros(n, d);
    let (mut h_out, mut c_out) = (vec![0.0f32; n * hdim], vec![0.0f32; n * hdim]);
    let iters = if smoke { 1 } else { (40_000_000 / (e * d)).clamp(12, 200) };
    // per-(kernel, thread) medians for the speedup extras, indexed by
    // THREADS position: [aggregate, matmul, fused, lstm]
    let mut med = [[[0.0f64; 2]; THREADS.len()]; 4];
    for (ti, t) in THREADS.into_iter().enumerate() {
        let engines = [Engine::new_with(t, Kernels::Scalar), Engine::new_with(t, Kernels::Lanes)];
        // bitwise gate: lanes ≡ scalar on these exact operands
        let want = engines[0].aggregate(&csr, &snap.selfcoef, &x);
        let got = engines[1].aggregate(&csr, &snap.selfcoef, &x);
        assert_eq!(
            got.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "lane aggregate diverged from scalar at t={t}"
        );
        let mut pw = Mat::zeros(n, d);
        engines[0].matmul_into(&x, &w, &mut proj);
        engines[1].matmul_into(&x, &w, &mut pw);
        assert_eq!(
            pw.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            proj.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "lane matmul diverged from scalar at t={t}"
        );
        for (ki, eng) in engines.iter().enumerate() {
            let kind = if ki == 0 { "scalar" } else { "lanes" };
            let rec = bench_loop_record(
                &format!("aggregate {kind} t={t} n={n} d={d}"),
                iters,
                || {
                    eng.aggregate_into(&csr, &snap.selfcoef, &x, &mut out);
                    out.data[0]
                },
            );
            med[0][ti][ki] = rec.median_s;
            records.push(rec);
            let rec = bench_loop_record(&format!("matmul {kind} t={t} n={n} d={d}"), iters, || {
                eng.matmul_into(&x, &w, &mut proj);
                proj.data[0]
            });
            med[1][ti][ki] = rec.median_s;
            records.push(rec);
            let rec = bench_loop_record(&format!("fused {kind} t={t} n={n} d={d}"), iters, || {
                eng.aggregate_matmul_into(&csr, &snap.selfcoef, &x, &w, &mut proj);
                proj.data[0]
            });
            med[2][ti][ki] = rec.median_s;
            records.push(rec);
            let rec = bench_loop_record(
                &format!("lstm-gate {kind} t={t} n={n} h={hdim}"),
                iters,
                || {
                    lstm_gate_slices_into(eng, &px, &ph, &b, &c, hdim, &mut h_out, &mut c_out);
                    h_out[0]
                },
            );
            med[3][ti][ki] = rec.median_s;
            records.push(rec);
        }
    }
    let simd_speedup = |k: usize, ti: usize| {
        if med[k][ti][1] > 0.0 { med[k][ti][0] / med[k][ti][1] } else { 0.0 }
    };

    // --- full vs delta-incremental CSR rebuild across churn ---------
    // The edit stream's forward deltas plus `EdgeDelta::between`-derived
    // backward deltas form a closed cycle, so the timed loop is pure
    // patch work (no full rebuild inside) and ends back at its starting
    // state every iteration.
    let (dn, ddeg) = (4096usize, 16usize);
    let de = dn * ddeg;
    let dsteps = if smoke { 3 } else { 6 };
    let diters = if smoke { 1 } else { 30 };
    let mut delta_speedups = [0.0f64; CHURNS.len()];
    for (ci, churn) in CHURNS.into_iter().enumerate() {
        let steps = edit_stream(&mut rng, dn, de, dsteps, churn);
        let mut cycle: Vec<(&Snapshot, EdgeDelta)> = Vec::new();
        for st in &steps[1..] {
            cycle.push((&st.snap, st.delta.clone()));
        }
        let mut scratch = SnapshotCsr::default();
        for i in (0..steps.len() - 1).rev() {
            scratch.rebuild(&steps[i + 1].snap);
            let back = EdgeDelta::between(&scratch, &steps[i].snap)
                .expect("edit stream keeps the node universe fixed");
            cycle.push((&steps[i].snap, back));
        }
        let mut full_csr = SnapshotCsr::default();
        let full = bench_loop_record(
            &format!("csr rebuild full churn={churn} n={dn} e={de}"),
            diters,
            || {
                for (snap, _) in &cycle {
                    full_csr.rebuild(snap);
                }
                full_csr.num_edges()
            },
        );
        let mut delta_csr = SnapshotCsr::default();
        delta_csr.rebuild(&steps[0].snap); // prime at the cycle's start state
        let mut patched = 0usize;
        let delta_rec = bench_loop_record(
            &format!("csr rebuild delta churn={churn} n={dn} e={de}"),
            diters,
            || {
                for (snap, delta) in &cycle {
                    patched += (delta_csr.rebuild_delta(snap, delta, DELTA_CHURN_ALL)
                        == CsrRebuild::Patched) as usize;
                }
                delta_csr.num_edges()
            },
        );
        // warmup call + timed iterations, every leg must have patched
        assert_eq!(
            patched,
            (diters.max(1) + 1) * cycle.len(),
            "delta rebuild fell back to full at churn={churn}"
        );
        // and the cycle really is closed: state is back at step 0
        let reference = SnapshotCsr::from_snapshot(&steps[0].snap);
        for r in 0..dn {
            let (gc, gv) = delta_csr.row(r);
            let (wc, wv) = reference.row(r);
            assert_eq!(gc, wc, "cycle did not close at row {r}");
            assert_eq!(
                gv.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                wv.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
        }
        delta_speedups[ci] =
            if delta_rec.median_s > 0.0 { full.median_s / delta_rec.median_s } else { 0.0 };
        records.push(full);
        records.push(delta_rec);
    }

    let speedup = if csr4_largest > 0.0 { coo_largest / csr4_largest } else { 0.0 };
    let speedup_into =
        if csr4_largest > 0.0 { coo_into_largest / csr4_largest } else { 0.0 };
    write_bench_json(
        "BENCH_kernels.json",
        &records,
        &[
            ("speedup_parallel_csr_vs_coo_largest", speedup),
            ("speedup_parallel_csr_vs_coo_into_largest", speedup_into),
            ("speedup_simd_matmul_t1", simd_speedup(1, 0)),
            ("speedup_simd_matmul_t2", simd_speedup(1, 1)),
            ("speedup_simd_matmul_t4", simd_speedup(1, 2)),
            ("speedup_simd_aggregate_t1", simd_speedup(0, 0)),
            ("speedup_simd_aggregate_t4", simd_speedup(0, 2)),
            ("speedup_simd_fused_t1", simd_speedup(2, 0)),
            ("speedup_simd_fused_t4", simd_speedup(2, 2)),
            ("speedup_simd_lstm_t1", simd_speedup(3, 0)),
            ("speedup_simd_lstm_t4", simd_speedup(3, 2)),
            ("speedup_delta_rebuild_churn_1pct", delta_speedups[0]),
            ("speedup_delta_rebuild_churn_5pct", delta_speedups[1]),
            ("speedup_delta_rebuild_churn_20pct", delta_speedups[2]),
            ("delta_rebuild_nodes", dn as f64),
            ("delta_rebuild_edges", de as f64),
            ("simd_default", if cfg!(feature = "simd") { 1.0 } else { 0.0 }),
            ("threads_max", *THREADS.last().unwrap() as f64),
            ("largest_nodes", n_big as f64),
            ("smoke", if smoke { 1.0 } else { 0.0 }),
        ],
    )
    .expect("write BENCH_kernels.json");
    println!(
        "wrote BENCH_kernels.json (parallel-CSR vs COO on n={n_big}: {speedup:.2}x \
         vs the shipped serial path, {speedup_into:.2}x vs the alloc-free walk, \
         at {} threads)",
        THREADS.last().unwrap()
    );
}
