//! L3 hot-path microbench: the PJRT step execution that sits on the
//! request path of the e2e server — literal creation, padding, execute,
//! readback.  This is the §Perf optimisation target for Layer 3.
//!
//! The end-to-end benches run the optimised steady-state path: a
//! persistent `StepRunner` (argument literals rewritten in place, `&mut`
//! out-buffers) and delta-aware `ResidentState` gathers.  Results are
//! also written to `BENCH_hotpath.json` (median + MAD per bench, plus
//! the measured shared-node fraction) so the perf trajectory is
//! machine-tracked across PRs.
//!
//! Requires `make artifacts`; prints a notice and exits cleanly if the
//! artifacts are absent (so `cargo bench` works in a fresh checkout).

use dgnn_booster::baselines::cpu::features_for;
use dgnn_booster::coordinator::{NodeStateStore, ResidentState};
use dgnn_booster::datasets::BC_ALPHA;
use dgnn_booster::fpga::incremental::{overlap_stats, DeltaStats};
use dgnn_booster::metrics::{bench_loop_record, write_bench_json, BenchRecord};
use dgnn_booster::models::{node_features_into, Dims, EvolveGcnParams, GcrnM2Params};
use dgnn_booster::report::tables::{snapshots, ReportCtx};
use dgnn_booster::runtime::{EvolveGcnExecutor, GcrnExecutor, Manifest, PaddedGraph, StagingSlot};

fn main() {
    if Manifest::load("artifacts").is_err() {
        println!("hotpath_pjrt: artifacts/ missing — run `make artifacts` first; skipping");
        return;
    }
    let ctx = ReportCtx::default();
    let dims = Dims::default();
    let mut snaps = snapshots(&ctx, &BC_ALPHA).expect("snaps");
    snaps.truncate(8);
    let client = xla::PjRtClient::cpu().expect("pjrt cpu client");
    let mut records: Vec<BenchRecord> = Vec::new();

    // measured shared-node fraction of the bench stream, reported
    // alongside the timings (the delta-gather win scales with it)
    let deltas = overlap_stats(&snaps);
    let shared_frac = deltas.iter().skip(1).map(DeltaStats::shared_frac).sum::<f64>()
        / deltas.len().saturating_sub(1).max(1) as f64;

    // EvolveGCN step — reused out-buffer, in-place argument staging
    let params = EvolveGcnParams::init(ctx.seed, dims);
    let mut exec = EvolveGcnExecutor::new(&client, "artifacts", &params).expect("executor");
    let xs: Vec<_> = snaps.iter().map(|s| features_for(s, dims, ctx.seed)).collect();
    let mut out = Vec::new();
    let mut i = 0;
    records.push(bench_loop_record("evolvegcn_step PJRT end-to-end", 50, || {
        let s = &snaps[i % snaps.len()];
        exec.run_step_into(s, &xs[i % snaps.len()].data, &mut out).unwrap();
        i += 1;
        out[0]
    }));

    // GCRN step — delta-aware resident state, no per-step gather allocation
    let gparams = GcrnM2Params::init(ctx.seed, dims);
    let mut gexec = GcrnExecutor::new(&client, "artifacts", &gparams).expect("executor");
    let max_nodes = gexec.manifest().max_nodes;
    let total = 4000;
    let mut h_store = NodeStateStore::zeros(total, dims.hidden_dim);
    let mut c_store = NodeStateStore::zeros(total, dims.hidden_dim);
    let mut h_res = ResidentState::new(max_nodes, dims.hidden_dim);
    let mut c_res = ResidentState::new(max_nodes, dims.hidden_dim);
    let mut i = 0;
    records.push(bench_loop_record("gcrn_m2_step PJRT end-to-end", 50, || {
        let s = &snaps[i % snaps.len()];
        h_res.advance(&mut h_store, s).unwrap();
        c_res.advance(&mut c_store, s).unwrap();
        gexec
            .run_step(s, &xs[i % snaps.len()].data, h_res.buf_mut(), c_res.buf_mut())
            .unwrap();
        i += 1;
        h_res.buf()[0]
    }));

    // padding-only component (to separate padding from PJRT costs)
    let manifest = gexec.manifest().clone();
    let mut pg = PaddedGraph::new(&manifest);
    let mut i = 0;
    records.push(bench_loop_record("PaddedGraph::fill (padding only)", 2000, || {
        let s = &snaps[i % snaps.len()];
        pg.fill(s).unwrap();
        i += 1;
        pg.num_edges
    }));

    // staging-only: padding + feature materialisation + delta advance —
    // the whole producer-side step path; zero heap allocation at steady
    // state (asserted by tests/alloc_hotpath.rs)
    let mut slot = StagingSlot::new(&manifest);
    let mut sh_store = NodeStateStore::zeros(total, dims.hidden_dim);
    let mut sh_res = ResidentState::new(manifest.max_nodes, dims.hidden_dim);
    let seed = ctx.seed;
    let mut i = 0;
    records.push(bench_loop_record("staging path (pad+features+delta)", 2000, || {
        let s = &snaps[i % snaps.len()];
        slot.stage(s, |raw, row| node_features_into(raw, seed, row)).unwrap();
        sh_res.advance(&mut sh_store, s).unwrap();
        i += 1;
        slot.graph.num_edges
    }));

    write_bench_json(
        "BENCH_hotpath.json",
        &records,
        &[
            ("shared_node_frac", shared_frac),
            ("snapshots", snaps.len() as f64),
        ],
    )
    .expect("write BENCH_hotpath.json");
    println!("wrote BENCH_hotpath.json (shared-node fraction {shared_frac:.3})");
}
