//! L3 hot-path microbench: the PJRT step execution that sits on the
//! request path of the e2e server — literal creation, padding, execute,
//! readback.  This is the §Perf optimisation target for Layer 3.
//!
//! Requires `make artifacts`; prints a notice and exits cleanly if the
//! artifacts are absent (so `cargo bench` works in a fresh checkout).

use dgnn_booster::baselines::cpu::features_for;
use dgnn_booster::metrics::bench_loop;
use dgnn_booster::models::{Dims, EvolveGcnParams, GcrnM2Params};
use dgnn_booster::report::tables::{snapshots, ReportCtx};
use dgnn_booster::runtime::{EvolveGcnExecutor, GcrnExecutor, Manifest};
use dgnn_booster::coordinator::NodeStateStore;
use dgnn_booster::datasets::BC_ALPHA;

fn main() {
    if Manifest::load("artifacts").is_err() {
        println!("hotpath_pjrt: artifacts/ missing — run `make artifacts` first; skipping");
        return;
    }
    let ctx = ReportCtx::default();
    let dims = Dims::default();
    let mut snaps = snapshots(&ctx, &BC_ALPHA).expect("snaps");
    snaps.truncate(8);
    let client = xla::PjRtClient::cpu().expect("pjrt cpu client");

    // EvolveGCN step
    let params = EvolveGcnParams::init(ctx.seed, dims);
    let mut exec = EvolveGcnExecutor::new(&client, "artifacts", &params).expect("executor");
    let xs: Vec<_> = snaps.iter().map(|s| features_for(s, dims, ctx.seed)).collect();
    let mut i = 0;
    bench_loop("evolvegcn_step PJRT end-to-end", 50, || {
        let s = &snaps[i % snaps.len()];
        let out = exec.run_step(s, &xs[i % snaps.len()].data).unwrap();
        i += 1;
        out[0]
    });

    // GCRN step
    let gparams = GcrnM2Params::init(ctx.seed, dims);
    let mut gexec = GcrnExecutor::new(&client, "artifacts", &gparams).expect("executor");
    let max_nodes = gexec.manifest().max_nodes;
    let total = 4000;
    let h_store = NodeStateStore::zeros(total, dims.hidden_dim);
    let c_store = NodeStateStore::zeros(total, dims.hidden_dim);
    let mut i = 0;
    bench_loop("gcrn_m2_step PJRT end-to-end", 50, || {
        let s = &snaps[i % snaps.len()];
        let mut h = h_store.gather_padded(s, max_nodes);
        let mut c = c_store.gather_padded(s, max_nodes);
        gexec.run_step(s, &xs[i % snaps.len()].data, &mut h, &mut c).unwrap();
        i += 1;
        h[0]
    });

    // padding-only component (to separate padding from PJRT costs)
    let manifest = gexec.manifest().clone();
    let mut pg = dgnn_booster::runtime::PaddedGraph::new(&manifest);
    let mut i = 0;
    bench_loop("PaddedGraph::fill (padding only)", 2000, || {
        let s = &snaps[i % snaps.len()];
        pg.fill(s).unwrap();
        i += 1;
        pg.num_edges
    });
}
