"""AOT lowering: JAX model steps -> HLO *text* artifacts for the Rust runtime.

HLO text (NOT ``lowered.compile().serialize()``) is the interchange format:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the xla
crate's bundled XLA (xla_extension 0.5.1) rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

Outputs (under ``artifacts/``):
  evolvegcn_step.hlo.txt   — V1 base model, per-snapshot step
  gcrn_m2_step.hlo.txt     — V2 base model, per-snapshot step
  gcrn_m1_step.hlo.txt     — stacked DGNN (runs on V1 and V2)
  gcn_forward.hlo.txt      — static 2-layer GCN (ablation baseline)
  manifest.txt             — shape/calling-convention manifest consumed by
                             rust/src/runtime/manifest.rs (simple key=value;
                             no serde available on the Rust side)

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _emit(fn, specs, path: str, donate=()) -> str:
    # donate recurrent-state buffers (h, c): lowers to input_output_alias
    # so PJRT can reuse the buffers instead of copying (§Perf L2 iter. 2)
    lowered = jax.jit(fn, donate_argnums=donate).lower(*specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return text


def build(out_dir: str, cfg: M.ModelConfig) -> None:
    os.makedirs(out_dir, exist_ok=True)

    jobs = [
        ("evolvegcn_step", M.evolvegcn_step, cfg.evolvegcn_arg_specs(), ()),
        ("gcrn_m2_step", M.gcrn_m2_step, cfg.gcrn_arg_specs(), (5, 6)),
        ("gcrn_m1_step", M.gcrn_m1_step, cfg.gcrn_m1_arg_specs(), (5, 6)),
        ("gcn_forward", M.gcn_forward, cfg.evolvegcn_arg_specs()[:7], ()),
    ]
    sizes = {}
    for name, fn, specs, donate in jobs:
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        text = _emit(fn, specs, path, donate)
        sizes[name] = len(text)
        print(f"wrote {path} ({len(text)} chars, {len(specs)} args)")

    manifest = os.path.join(out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("# DGNN-Booster AOT artifact manifest (key=value)\n")
        f.write(f"max_nodes={cfg.max_nodes}\n")
        f.write(f"max_edges={cfg.max_edges}\n")
        f.write(f"in_dim={cfg.in_dim}\n")
        f.write(f"hidden_dim={cfg.hidden_dim}\n")
        f.write(f"out_dim={cfg.out_dim}\n")
        f.write("evolvegcn_step.args=src:i32[E];dst:i32[E];coef:f32[E];"
                "selfcoef:f32[N];x:f32[N,IN];w1:f32[IN,H];w2:f32[H,OUT];"
                "gru1:9xf32;gru2:9xf32\n")
        f.write("evolvegcn_step.outs=out:f32[N,OUT];w1:f32[IN,H];"
                "w2:f32[H,OUT]\n")
        f.write("gcrn_m1_step.args=src;dst;coef;selfcoef;x;h;c;w1;w2;wx;wh;b\n")
        f.write("gcrn_m1_step.outs=h:f32[N,H];c:f32[N,H]\n")
        f.write("gcrn_m2_step.args=src:i32[E];dst:i32[E];coef:f32[E];"
                "selfcoef:f32[N];x:f32[N,IN];h:f32[N,H];c:f32[N,H];wx:f32[IN,4H];"
                "wh:f32[H,4H];b:f32[4H]\n")
        f.write("gcrn_m2_step.outs=h:f32[N,H];c:f32[N,H]\n")
        f.write("gcn_forward.args=src;dst;coef;selfcoef;x;w1;w2\n")
        f.write("gcn_forward.outs=out:f32[N,OUT]\n")
    print(f"wrote {manifest}")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts")
    p.add_argument("--max-nodes", type=int, default=608)
    p.add_argument("--max-edges", type=int, default=1728)
    p.add_argument("--dim", type=int, default=32)
    a = p.parse_args()
    cfg = M.ModelConfig(
        max_nodes=a.max_nodes, max_edges=a.max_edges,
        in_dim=a.dim, hidden_dim=a.dim, out_dim=a.dim,
    )
    build(a.out, cfg)


if __name__ == "__main__":
    main()
