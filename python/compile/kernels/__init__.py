"""Layer-1 Pallas kernels for DGNN-Booster.

Every kernel here is the hardware analog of one DGNN-Booster processing
element (PE):

- :mod:`matmul`            — node-transformation (NT) PE: tiled dense matmul.
- :mod:`message_passing`   — message-passing (MP) PE: CSR-style
                             gather / edge-weight / scatter-accumulate.
- :mod:`gru`               — EvolveGCN weight-evolution PE: fused matrix-GRU.
- :mod:`lstm`              — GCRN-M2 temporal PE: fused LSTM gate stage.

All kernels are lowered with ``interpret=True`` so they become plain HLO and
run on the CPU PJRT client the Rust coordinator uses (real-TPU Mosaic
lowering is compile-only in this environment; see DESIGN.md
§Hardware-Adaptation).
"""

from . import matmul, message_passing, gru, lstm, ref  # noqa: F401
