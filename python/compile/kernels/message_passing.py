"""Message-passing (MP) Pallas kernel — gather / edge-weight / scatter.

The paper implements GCN aggregation GenGNN-style: for every edge
``(s, d)`` with normalisation coefficient ``c`` (which also carries the
edge embedding — DGNN-Booster folds edge features into the message), the
MP PE gathers ``x[s]``, scales it by ``c``, and accumulates into
``agg[d]``.  Padded edges carry ``c == 0`` so fixed-shape AOT artifacts
are mask-correct by construction.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the ZCU102 design streams
edges through a gather unit against a BRAM-resident node buffer.  Here the
node buffer lives in VMEM for the whole kernel invocation and the edge
list streams through a ``fori_loop`` — a sequential read-modify-write
chain, exactly the dependency structure the FPGA resolves with its
accumulator port.  ``interpret=True`` lowers the loop to an HLO while-loop
with dynamic-slice updates, which XLA:CPU runs natively.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mp_kernel_vector(src_ref, dst_ref, coef_ref, x_ref, o_ref):
    """Vectorised gather / scale / scatter-add over the whole edge block.

    §Perf L1 iteration 1 (EXPERIMENTS.md): the edge-streaming formulation
    below lowers to an HLO while-loop with one dynamic-update-slice per
    edge — 1728 serial iterations per conv on the padded shapes, which
    made the PJRT step ~31 ms.  This variant keeps the node buffer
    VMEM-resident and streams the edge list through a *wide* gather and a
    single scatter-accumulate, the same dataflow the MP PE implements
    with its d-wide gather lanes; XLA lowers it to one gather + one
    scatter (~40× faster on the CPU client).
    """
    msgs = coef_ref[...][:, None] * x_ref[...][src_ref[...], :]
    o_ref[...] = jnp.zeros_like(o_ref).at[dst_ref[...]].add(msgs)


def _mp_kernel_stream(src_ref, dst_ref, coef_ref, x_ref, o_ref):
    """agg[dst[e]] += coef[e] * x[src[e]] edge by edge — the literal
    hardware formulation (one edge per cycle through the gather unit);
    kept for fidelity tests and as the timing model's reference shape."""
    o_ref[...] = jnp.zeros_like(o_ref)
    n_edges = src_ref.shape[0]

    def body(e, _):
        s = src_ref[e]
        d = dst_ref[e]
        c = coef_ref[e]
        msg = c * pl.load(x_ref, (pl.dslice(s, 1), slice(None)))
        acc = pl.load(o_ref, (pl.dslice(d, 1), slice(None)))
        pl.store(o_ref, (pl.dslice(d, 1), slice(None)), acc + msg)
        return 0

    jax.lax.fori_loop(0, n_edges, body, 0)


def _mp_call(kernel, src, dst, coef, x):
    n, d = x.shape
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=True,
    )(src, dst, coef, x)


@jax.jit
def message_passing(
    src: jax.Array, dst: jax.Array, coef: jax.Array, x: jax.Array
) -> jax.Array:
    """Edge-wise scatter-accumulate: ``agg[d] = Σ_{(s,d)∈E} coef·x[s]``.

    Args:
      src:  [e] int32 source node index per edge (renumbered, on-chip ids).
      dst:  [e] int32 destination node index per edge.
      coef: [e] float32 per-edge coefficient = Â entry × edge embedding;
            zero for padding edges.
      x:    [n, d] float32 node embeddings (padded).

    Returns:
      [n, d] float32 aggregated embeddings.
    """
    return _mp_call(_mp_kernel_vector, src, dst, coef, x)


@jax.jit
def message_passing_stream(
    src: jax.Array, dst: jax.Array, coef: jax.Array, x: jax.Array
) -> jax.Array:
    """Edge-streaming variant (see `_mp_kernel_stream`); numerically
    identical to :func:`message_passing`, asserted by the test suite."""
    return _mp_call(_mp_kernel_stream, src, dst, coef, x)


@jax.jit
def aggregate(
    src: jax.Array,
    dst: jax.Array,
    coef: jax.Array,
    selfcoef: jax.Array,
    x: jax.Array,
) -> jax.Array:
    """Full Â·X: edge messages plus the self-loop diagonal term.

    Self-loops are *not* materialised in the edge list (that would
    overflow the fixed MAX_EDGES budget when a snapshot is near both its
    node and edge maxima); instead the host preprocessor emits a per-node
    diagonal coefficient ``selfcoef[i] = Â_{ii}`` (zero for padded nodes)
    and the diagonal term is a fused elementwise multiply-add.
    """
    return message_passing(src, dst, coef, x) + selfcoef[:, None] * x


@functools.partial(jax.jit, static_argnames=("relu",))
def gcn_layer(
    src: jax.Array,
    dst: jax.Array,
    coef: jax.Array,
    selfcoef: jax.Array,
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    relu: bool = False,
) -> jax.Array:
    """One GCN layer ``act((Â·X)W + b)`` = MP PE feeding the NT PE.

    This is exactly the paper's two-stage GNN pipeline: in DGNN-Booster V2
    the two stages are FIFO-coupled at node granularity; numerically the
    composition is identical.
    """
    from . import matmul as mm

    agg = aggregate(src, dst, coef, selfcoef, x)
    return mm.matmul_bias_act(agg, w, b, relu=relu)
