"""Fused LSTM gate-stage Pallas kernel — GCRN-M2 temporal PE.

GCRN-M2 (paper eq. (3)) replaces the LSTM's dense input/hidden projections
with graph convolutions: the gate pre-activations are

    P_x = (Â·X^t)  Wx   ∈ [n, 4h]      (GNN1 in the paper)
    P_h = (Â·H^t)  Wh   ∈ [n, 4h]      (GNN2 in the paper)

computed by the MP + NT PEs, and the recurrent *elementwise* stage

    i, f, g, o = split(P_x + P_h + b)
    C' = σ(f)⊙C + σ(i)⊙tanh(g)
    H' = σ(o)⊙tanh(C')

is this kernel.  On the ZCU102 these stages are FIFO-pipelined at node
granularity (Pipeline-O1); here they fuse into a single VMEM-resident
kernel tiled over node rows, so each node row makes exactly one HBM
round-trip — the same memory-traffic shape the FPGA pipeline achieves.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lstm_kernel(px_ref, ph_ref, b_ref, c_ref, h_out_ref, c_out_ref):
    h4 = px_ref.shape[1]
    h = h4 // 4
    pre = px_ref[...] + ph_ref[...] + b_ref[...]
    i = jax.nn.sigmoid(pre[:, 0 * h:1 * h])
    f = jax.nn.sigmoid(pre[:, 1 * h:2 * h])
    g = jnp.tanh(pre[:, 2 * h:3 * h])
    o = jax.nn.sigmoid(pre[:, 3 * h:4 * h])
    c_new = f * c_ref[...] + i * g
    c_out_ref[...] = c_new
    h_out_ref[...] = o * jnp.tanh(c_new)


def _pick_block_m(m: int) -> int:
    for cand in (256, 128, 64, 32, 16, 8):
        if m % cand == 0:
            return cand
    return m


@functools.partial(jax.jit, static_argnames=("block_m",))
def lstm_gate_stage(
    px: jax.Array,
    ph: jax.Array,
    b: jax.Array,
    c: jax.Array,
    *,
    block_m: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused LSTM elementwise stage.

    Args:
      px: [n, 4h] input-side gate pre-activations (gate order i,f,g,o).
      ph: [n, 4h] hidden-side gate pre-activations.
      b:  [4h] gate biases.
      c:  [n, h] previous cell state.

    Returns:
      (h_new, c_new), each [n, h].
    """
    n, h4 = px.shape
    hdim = h4 // 4
    bm = block_m or _pick_block_m(n)
    h_new, c_new = pl.pallas_call(
        _lstm_kernel,
        grid=(n // bm,),
        in_specs=[
            pl.BlockSpec((bm, h4), lambda i: (i, 0)),
            pl.BlockSpec((bm, h4), lambda i: (i, 0)),
            pl.BlockSpec((1, h4), lambda i: (0, 0)),
            pl.BlockSpec((bm, hdim), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, hdim), lambda i: (i, 0)),
            pl.BlockSpec((bm, hdim), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, hdim), jnp.float32),
            jax.ShapeDtypeStruct((n, hdim), jnp.float32),
        ],
        interpret=True,
    )(px, ph, b.reshape(1, h4), c)
    return h_new, c_new
