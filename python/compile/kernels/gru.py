"""Fused matrix-GRU Pallas kernel — EvolveGCN-O weight evolution PE.

EvolveGCN-O evolves each GCN layer's weight matrix with a GRU in which the
weight matrix is *both* the input and the hidden state (paper eq. (4):
``W^t = RNN(W^{t-1})``).  Following the official EvolveGCN implementation,
the cell is a *matrix* GRU: parameters are [rows, rows] matrices applied
from the left, biases are full [rows, cols] matrices:

    Z = sigmoid(Wz·H + Uz·H + Bz)
    R = sigmoid(Wr·H + Ur·H + Br)
    H~ = tanh(Wh·H + Uh·(R ⊙ H) + Bh)
    H' = (1 − Z) ⊙ H + Z ⊙ H~

The whole cell is one Pallas kernel: for d=32 every operand fits in a
single VMEM tile, so the fusion removes five intermediate HBM round-trips
— the TPU analog of the paper's stage-pipelined RNN PE with LUTRAM-resident
weights.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gru_kernel(h_ref, wz_ref, uz_ref, bz_ref, wr_ref, ur_ref, br_ref,
                wh_ref, uh_ref, bh_ref, o_ref):
    h = h_ref[...]
    dot = lambda a, b: jnp.dot(a, b, preferred_element_type=jnp.float32)
    z = jax.nn.sigmoid(dot(wz_ref[...], h) + dot(uz_ref[...], h) + bz_ref[...])
    r = jax.nn.sigmoid(dot(wr_ref[...], h) + dot(ur_ref[...], h) + br_ref[...])
    htil = jnp.tanh(dot(wh_ref[...], h) + dot(uh_ref[...], r * h) + bh_ref[...])
    o_ref[...] = (1.0 - z) * h + z * htil


@jax.jit
def gru_matrix_cell(h: jax.Array, params: dict[str, jax.Array]) -> jax.Array:
    """One matrix-GRU step: evolve weight matrix ``h`` -> ``h'``.

    Args:
      h: [rows, cols] float32 — the GCN weight matrix being evolved.
      params: dict with 'wz','uz','bz','wr','ur','br','wh','uh','bh';
        W*/U* are [rows, rows], B* are [rows, cols].
    """
    rows, cols = h.shape
    args = [h] + [params[k] for k in
                  ("wz", "uz", "bz", "wr", "ur", "br", "wh", "uh", "bh")]
    return pl.pallas_call(
        _gru_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(*args)


def gru_param_keys() -> tuple[str, ...]:
    """Canonical parameter ordering used by the AOT interface."""
    return ("wz", "uz", "bz", "wr", "ur", "br", "wh", "uh", "bh")
