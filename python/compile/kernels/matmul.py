"""Tiled dense-matmul Pallas kernel — the node-transformation (NT) PE.

DGNN-Booster's node transformation is ``X' = (ÂX) W`` — a dense
[n, d_in] x [d_in, d_out] matmul fed by the message-passing PE.  On the
ZCU102 this is a DSP systolic array; on the TPU analog we tile for the
MXU: the M dimension is blocked so each grid step holds one
(block_m, d_in) activation tile plus the whole (d_in, d_out) weight
panel in VMEM, and accumulation happens in a VMEM scratch block.

The kernel is shape-generic; `python/compile/aot.py` instantiates it at
the padded snapshot shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One grid step: o[block_m, n] = x[block_m, k] @ w[k, n]."""
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pick_block_m(m: int) -> int:
    """Largest MXU-friendly block that divides m (m is padded to 8|m)."""
    for cand in (256, 128, 64, 32, 16, 8):
        if m % cand == 0:
            return cand
    return m


@functools.partial(jax.jit, static_argnames=("block_m",))
def matmul(x: jax.Array, w: jax.Array, *, block_m: int | None = None) -> jax.Array:
    """``x @ w`` via a Pallas kernel tiled over rows of ``x``.

    Args:
      x: [m, k] float32 activations (m should be a multiple of 8).
      w: [k, n] float32 weight panel (kept whole in VMEM — DGNN dims are
         small, <= 32x128 here, exactly the paper's LUTRAM-resident weights).
      block_m: row-tile size; auto-picked if None.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = block_m or _pick_block_m(m)
    grid = (m // bm,)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


@functools.partial(jax.jit, static_argnames=("block_m", "relu"))
def matmul_bias_act(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    relu: bool = False,
    block_m: int | None = None,
) -> jax.Array:
    """Fused ``act(x @ w + b)`` — one VMEM round-trip for the NT PE."""
    m, k = x.shape
    _, n = w.shape
    bm = block_m or _pick_block_m(m)

    def kernel(x_ref, w_ref, b_ref, o_ref):
        acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
        acc = acc + b_ref[...]
        if relu:
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc

    return pl.pallas_call(
        kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b.reshape(1, n))
