"""Pure-jnp oracle for every Pallas kernel and both model steps.

This is the reproduction's stand-in for the paper's "crosschecking with
PyTorch code": every kernel in this package and every model step in
``model.py`` must match these reference implementations to float32
tolerance (enforced by ``python/tests/``), and the Rust mirror in
``rust/src/numerics/`` must match the HLO artifacts built from them
(enforced by ``rust/tests/``).

No Pallas, no pallas_call — jnp only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- kernels

def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return x @ w


def matmul_bias_act_ref(x, w, b, *, relu=False):
    out = x @ w + b
    return jnp.maximum(out, 0.0) if relu else out


def message_passing_ref(src, dst, coef, x):
    """agg[d] = sum over edges (s,d): coef * x[s]  (scatter-add)."""
    msgs = coef[:, None] * x[src]
    return jnp.zeros_like(x).at[dst].add(msgs)


def aggregate_ref(src, dst, coef, selfcoef, x):
    return message_passing_ref(src, dst, coef, x) + selfcoef[:, None] * x


def gcn_layer_ref(src, dst, coef, selfcoef, x, w, b, *, relu=False):
    agg = aggregate_ref(src, dst, coef, selfcoef, x)
    return matmul_bias_act_ref(agg, w, b, relu=relu)


def gru_matrix_cell_ref(h, params):
    z = jax.nn.sigmoid(params["wz"] @ h + params["uz"] @ h + params["bz"])
    r = jax.nn.sigmoid(params["wr"] @ h + params["ur"] @ h + params["br"])
    htil = jnp.tanh(params["wh"] @ h + params["uh"] @ (r * h) + params["bh"])
    return (1.0 - z) * h + z * htil


def lstm_gate_stage_ref(px, ph, b, c):
    h4 = px.shape[1]
    hdim = h4 // 4
    pre = px + ph + b
    i = jax.nn.sigmoid(pre[:, 0 * hdim:1 * hdim])
    f = jax.nn.sigmoid(pre[:, 1 * hdim:2 * hdim])
    g = jnp.tanh(pre[:, 2 * hdim:3 * hdim])
    o = jax.nn.sigmoid(pre[:, 3 * hdim:4 * hdim])
    c_new = f * c + i * g
    return o * jnp.tanh(c_new), c_new


# ------------------------------------------------------------ model steps

def evolvegcn_step_ref(src, dst, coef, selfcoef, x, w1, w2, gru1, gru2):
    """EvolveGCN-O: evolve both layer weights, then run the 2-layer GCN."""
    w1n = gru_matrix_cell_ref(w1, gru1)
    w2n = gru_matrix_cell_ref(w2, gru2)
    zeros1 = jnp.zeros((w1n.shape[1],), jnp.float32)
    zeros2 = jnp.zeros((w2n.shape[1],), jnp.float32)
    h1 = gcn_layer_ref(src, dst, coef, selfcoef, x, w1n, zeros1, relu=True)
    h2 = gcn_layer_ref(src, dst, coef, selfcoef, h1, w2n, zeros2, relu=False)
    return h2, w1n, w2n


def gcrn_m1_step_ref(src, dst, coef, selfcoef, x, h, c, w1, w2, wx, wh, b):
    """GCRN-M1 (stacked): 2-layer GCN then a dense per-node LSTM."""
    zeros1 = jnp.zeros((w1.shape[1],), jnp.float32)
    zeros2 = jnp.zeros((w2.shape[1],), jnp.float32)
    x1 = gcn_layer_ref(src, dst, coef, selfcoef, x, w1, zeros1, relu=True)
    x2 = gcn_layer_ref(src, dst, coef, selfcoef, x1, w2, zeros2, relu=False)
    return lstm_gate_stage_ref(x2 @ wx, h @ wh, b, c)


def gcrn_m2_step_ref(src, dst, coef, selfcoef, x, h, c, wx, wh, b):
    """GCRN-M2: graph-conv LSTM step (GNN1 on X, GNN2 on H, fused gates)."""
    px = aggregate_ref(src, dst, coef, selfcoef, x) @ wx
    ph = aggregate_ref(src, dst, coef, selfcoef, h) @ wh
    return lstm_gate_stage_ref(px, ph, b, c)
