"""Layer-2 JAX model: per-snapshot step functions for both DGNN models.

The temporal loop over snapshots lives in the Rust coordinator (L3) —
snapshot count T is dynamic and graphs stream in, exactly as on the
paper's CPU-FPGA platform.  Python only defines the *per-snapshot* step
(one ``G^t`` in, evolved state out) at fixed padded shapes, calling the
Pallas PE kernels, and is AOT-lowered once by ``aot.py``.

Shapes (defaults; see :class:`ModelConfig`):
  MAX_NODES = 608   — covers BC-Alpha max 578 / UCI max 501 (Table III)
  MAX_EDGES = 1728  — covers BC-Alpha max 1686 / UCI max 1534; self-loop
                      terms travel as a per-node `selfcoef` diagonal, not
                      as edge-list entries, so they never inflate the list
  D = 32            — in/hidden/out feature dim (EvolveGCN defaults)

Padding contract (mask-correctness, property-tested in python/tests and
rust/tests):
  * padded edges have src = dst = 0 and coef = 0.0 → contribute nothing;
  * padded node rows may hold garbage; consumers mask by node count.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .kernels import gru as gru_k
from .kernels import lstm as lstm_k
from .kernels import matmul as mm_k
from .kernels import message_passing as mp_k


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static shape configuration shared with the Rust runtime."""

    max_nodes: int = 608
    max_edges: int = 1728
    in_dim: int = 32
    hidden_dim: int = 32
    out_dim: int = 32

    def evolvegcn_arg_specs(self):
        """Argument ShapeDtypeStructs, in AOT calling order."""
        f32, i32 = jnp.float32, jnp.int32
        s = jax.ShapeDtypeStruct
        specs = [
            s((self.max_edges,), i32),                   # src
            s((self.max_edges,), i32),                   # dst
            s((self.max_edges,), f32),                   # coef
            s((self.max_nodes,), f32),                   # selfcoef
            s((self.max_nodes, self.in_dim), f32),       # x
            s((self.in_dim, self.hidden_dim), f32),      # w1
            s((self.hidden_dim, self.out_dim), f32),     # w2
        ]
        # gru1 params (on w1: rows=in_dim, cols=hidden_dim)
        for k in gru_k.gru_param_keys():
            rows = self.in_dim
            cols = self.hidden_dim
            shape = (rows, cols) if k.startswith("b") else (rows, rows)
            specs.append(s(shape, f32))
        # gru2 params (on w2: rows=hidden_dim, cols=out_dim)
        for k in gru_k.gru_param_keys():
            rows = self.hidden_dim
            cols = self.out_dim
            shape = (rows, cols) if k.startswith("b") else (rows, rows)
            specs.append(s(shape, f32))
        return specs

    def gcrn_m1_arg_specs(self):
        f32, i32 = jnp.float32, jnp.int32
        s = jax.ShapeDtypeStruct
        return [
            s((self.max_edges,), i32),                         # src
            s((self.max_edges,), i32),                         # dst
            s((self.max_edges,), f32),                         # coef
            s((self.max_nodes,), f32),                         # selfcoef
            s((self.max_nodes, self.in_dim), f32),             # x
            s((self.max_nodes, self.hidden_dim), f32),         # h
            s((self.max_nodes, self.hidden_dim), f32),         # c
            s((self.in_dim, self.hidden_dim), f32),            # w1
            s((self.hidden_dim, self.out_dim), f32),           # w2
            s((self.out_dim, 4 * self.hidden_dim), f32),       # wx
            s((self.hidden_dim, 4 * self.hidden_dim), f32),    # wh
            s((4 * self.hidden_dim,), f32),                    # b
        ]

    def gcrn_arg_specs(self):
        f32, i32 = jnp.float32, jnp.int32
        s = jax.ShapeDtypeStruct
        return [
            s((self.max_edges,), i32),                         # src
            s((self.max_edges,), i32),                         # dst
            s((self.max_edges,), f32),                         # coef
            s((self.max_nodes,), f32),                         # selfcoef
            s((self.max_nodes, self.in_dim), f32),             # x
            s((self.max_nodes, self.hidden_dim), f32),         # h
            s((self.max_nodes, self.hidden_dim), f32),         # c
            s((self.in_dim, 4 * self.hidden_dim), f32),        # wx
            s((self.hidden_dim, 4 * self.hidden_dim), f32),    # wh
            s((4 * self.hidden_dim,), f32),                    # b
        ]


def _unpack_gru(flat, rows, cols):
    params = {}
    for i, k in enumerate(gru_k.gru_param_keys()):
        params[k] = flat[i]
    return params


def evolvegcn_step(src, dst, coef, selfcoef, x, w1, w2, *gru_flat):
    """One EvolveGCN-O snapshot step (DGNN-Booster V1's base model).

    Weight evolution (matrix-GRU PE) is independent of the snapshot's
    graph — that independence is exactly what V1 exploits by overlapping
    ``RNN(t+1)`` with ``MP(t)`` across ping-pong weight buffers.

    Returns (out [n, out_dim], w1_new, w2_new) as a tuple.
    """
    n_gru = len(gru_k.gru_param_keys())
    gru1 = _unpack_gru(gru_flat[:n_gru], *w1.shape)
    gru2 = _unpack_gru(gru_flat[n_gru:], *w2.shape)
    w1n = gru_k.gru_matrix_cell(w1, gru1)
    w2n = gru_k.gru_matrix_cell(w2, gru2)
    zeros1 = jnp.zeros((w1n.shape[1],), jnp.float32)
    zeros2 = jnp.zeros((w2n.shape[1],), jnp.float32)
    h1 = mp_k.gcn_layer(src, dst, coef, selfcoef, x, w1n, zeros1, relu=True)
    h2 = mp_k.gcn_layer(src, dst, coef, selfcoef, h1, w2n, zeros2, relu=False)
    return h2, w1n, w2n


def gcrn_m2_step(src, dst, coef, selfcoef, x, h, c, wx, wh, b):
    """One GCRN-M2 snapshot step (DGNN-Booster V2's base model).

    GNN1 (on X) and GNN2 (on H) feed the fused LSTM gate stage — the
    three units V2 couples with node queues.

    Returns (h_new, c_new).
    """
    agg_x = mp_k.aggregate(src, dst, coef, selfcoef, x)
    agg_h = mp_k.aggregate(src, dst, coef, selfcoef, h)
    px = mm_k.matmul(agg_x, wx)
    ph = mm_k.matmul(agg_h, wh)
    h_new, c_new = lstm_k.lstm_gate_stage(px, ph, b, c)
    return h_new, c_new


def gcrn_m1_step(src, dst, coef, selfcoef, x, h, c, w1, w2, wx, wh, b):
    """One GCRN-M1 snapshot step — the *stacked* DGNN of Table I.

    GNN (2-layer GCN) encodes the snapshot, then a conventional dense
    LSTM evolves per-node temporal state:

        X' = GCN(G_t, X_t);  i,f,g,o = X'Wx + H Wh + b;  (H', C') = LSTM

    Because the GNN never reads the RNN state, consecutive snapshots'
    GNNs are independent — the property that makes stacked DGNNs eligible
    for BOTH DGNN-Booster designs (V1 adjacent-step overlap and V2
    within-step node queues).

    Returns (h_new, c_new).
    """
    z1 = jnp.zeros((w1.shape[1],), jnp.float32)
    z2 = jnp.zeros((w2.shape[1],), jnp.float32)
    x1 = mp_k.gcn_layer(src, dst, coef, selfcoef, x, w1, z1, relu=True)
    x2 = mp_k.gcn_layer(src, dst, coef, selfcoef, x1, w2, z2, relu=False)
    px = mm_k.matmul(x2, wx)
    ph = mm_k.matmul(h, wh)
    h_new, c_new = lstm_k.lstm_gate_stage(px, ph, b, c)
    return h_new, c_new


def gcn_forward(src, dst, coef, selfcoef, x, w1, w2):
    """Plain 2-layer GCN forward (no temporal part) — used by micro-benches
    and as the static-GNN baseline in the ablation harness."""
    z1 = jnp.zeros((w1.shape[1],), jnp.float32)
    z2 = jnp.zeros((w2.shape[1],), jnp.float32)
    h1 = mp_k.gcn_layer(src, dst, coef, selfcoef, x, w1, z1, relu=True)
    return (mp_k.gcn_layer(src, dst, coef, selfcoef, h1, w2, z2, relu=False),)
