"""AOT pipeline tests: lowering succeeds, manifest is consistent, and the
HLO text actually contains an entry computation with the right arity."""

import os
import re
import tempfile

import pytest

from compile import aot
from compile import model as M

SMALL = M.ModelConfig(max_nodes=32, max_edges=64, in_dim=8,
                      hidden_dim=8, out_dim=8)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.build(out, SMALL)
    return out


def test_all_artifacts_emitted(built):
    for name in ("evolvegcn_step", "gcrn_m2_step", "gcn_forward"):
        p = os.path.join(built, f"{name}.hlo.txt")
        assert os.path.exists(p) and os.path.getsize(p) > 1000


def test_hlo_text_has_entry(built):
    text = open(os.path.join(built, "gcrn_m2_step.hlo.txt")).read()
    assert "ENTRY" in text
    assert "f32[32,8]" in text  # node-embedding operand shape


def test_hlo_param_count_matches_spec(built):
    text = open(os.path.join(built, "evolvegcn_step.hlo.txt")).read()
    entry = text[text.index("ENTRY"):]
    params = re.findall(r"parameter\(\d+\)", entry)
    assert len(params) == len(SMALL.evolvegcn_arg_specs()) == 25


def test_manifest_roundtrip(built):
    kv = {}
    for line in open(os.path.join(built, "manifest.txt")):
        if "=" in line and not line.startswith("#"):
            k, v = line.rstrip("\n").split("=", 1)
            kv[k] = v
    assert kv["max_nodes"] == "32"
    assert kv["max_edges"] == "64"
    assert "evolvegcn_step.args" in kv
    assert kv["gcrn_m2_step.outs"] == "h:f32[N,H];c:f32[N,H]"


def test_hlo_is_plain_hlo_no_custom_call(built):
    """interpret=True must have erased all Pallas/Mosaic custom-calls; a
    custom-call would be unloadable by the CPU PJRT client."""
    for name in ("evolvegcn_step", "gcrn_m2_step", "gcn_forward"):
        text = open(os.path.join(built, f"{name}.hlo.txt")).read()
        assert "custom-call" not in text, f"{name} contains a custom-call"
