"""RNN-PE kernels (matrix-GRU, fused LSTM gate stage) vs oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import gru, lstm, ref

from .conftest import dims, seeds

TOL = dict(rtol=1e-5, atol=1e-5)


def _gru_params(rng, rows, cols, scale=0.2):
    p = {}
    for k in gru.gru_param_keys():
        shape = (rows, cols) if k.startswith("b") else (rows, rows)
        p[k] = jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)
    return p


@settings(max_examples=25, deadline=None)
@given(rows=dims(1, 48), cols=dims(1, 48), seed=seeds())
def test_gru_matches_ref(rows, cols, seed):
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.normal(size=(rows, cols)), jnp.float32)
    p = _gru_params(rng, rows, cols)
    np.testing.assert_allclose(
        gru.gru_matrix_cell(h, p), ref.gru_matrix_cell_ref(h, p), **TOL)


def test_gru_zero_gate_keeps_state(rng):
    """With all params zero: Z = σ(0) = ½, H~ = 0, so H' = H/2."""
    h = jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)
    p = {k: jnp.zeros((16, 16), jnp.float32) for k in gru.gru_param_keys()}
    np.testing.assert_allclose(gru.gru_matrix_cell(h, p), 0.5 * np.asarray(h), **TOL)


def test_gru_output_bounded_under_saturation(rng):
    """Even with huge params, H' is a convex combo of H and tanh output,
    so |H'| <= max(|H|, 1)."""
    h = jnp.asarray(rng.normal(size=(8, 8)) * 0.5, jnp.float32)
    p = _gru_params(rng, 8, 8, scale=100.0)
    out = np.asarray(gru.gru_matrix_cell(h, p))
    assert (np.abs(out) <= np.maximum(np.abs(np.asarray(h)), 1.0) + 1e-6).all()


@settings(max_examples=25, deadline=None)
@given(n=dims(8, 128, multiple_of=8), h=dims(1, 32), seed=seeds())
def test_lstm_matches_ref(n, h, seed):
    rng = np.random.default_rng(seed)
    px = jnp.asarray(rng.normal(size=(n, 4 * h)), jnp.float32)
    ph = jnp.asarray(rng.normal(size=(n, 4 * h)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(4 * h,)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(n, h)), jnp.float32)
    got_h, got_c = lstm.lstm_gate_stage(px, ph, b, c)
    want_h, want_c = ref.lstm_gate_stage_ref(px, ph, b, c)
    np.testing.assert_allclose(got_h, want_h, **TOL)
    np.testing.assert_allclose(got_c, want_c, **TOL)


def test_lstm_forget_gate_saturated_keeps_cell(rng):
    """f→1, i→0: C' = C exactly (up to σ saturation)."""
    n, h = 8, 4
    big = 50.0
    px = np.zeros((n, 4 * h), np.float32)
    px[:, 0 * h:1 * h] = -big   # i -> 0
    px[:, 1 * h:2 * h] = +big   # f -> 1
    px[:, 3 * h:4 * h] = -big   # o -> 0
    c = rng.normal(size=(n, h)).astype(np.float32)
    got_h, got_c = lstm.lstm_gate_stage(
        jnp.asarray(px), jnp.zeros((n, 4 * h), jnp.float32),
        jnp.zeros((4 * h,), jnp.float32), jnp.asarray(c))
    np.testing.assert_allclose(got_c, c, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got_h, np.zeros_like(c), atol=1e-4)


def test_lstm_hidden_bounded(rng):
    """|H'| <= 1 always (σ(o) * tanh(C'))."""
    n, h = 16, 8
    px = jnp.asarray(rng.normal(size=(n, 4 * h)) * 10, jnp.float32)
    ph = jnp.asarray(rng.normal(size=(n, 4 * h)) * 10, jnp.float32)
    b = jnp.asarray(rng.normal(size=(4 * h,)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(n, h)) * 10, jnp.float32)
    got_h, _ = lstm.lstm_gate_stage(px, ph, b, c)
    assert (np.abs(np.asarray(got_h)) <= 1.0 + 1e-6).all()
