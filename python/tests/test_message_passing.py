"""MP-PE (gather/scatter message passing) kernel vs pure-jnp oracle.

This kernel carries the padding contract for the whole AOT interface:
edges with coef == 0 must contribute nothing, regardless of their
src/dst indices.  Hypothesis sweeps graph sizes, densities and padding
fractions.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import message_passing as mp
from compile.kernels import ref

from .conftest import dims, seeds

TOL = dict(rtol=1e-4, atol=1e-4)


def _graph(rng, n, e, d, pad_frac=0.0):
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    coef = (rng.normal(size=e) * 0.5).astype(np.float32)
    n_pad = int(e * pad_frac)
    if n_pad:
        coef[e - n_pad:] = 0.0
        src[e - n_pad:] = 0
        dst[e - n_pad:] = 0
    x = rng.normal(size=(n, d)).astype(np.float32)
    return (jnp.asarray(src), jnp.asarray(dst), jnp.asarray(coef),
            jnp.asarray(x))


@settings(max_examples=25, deadline=None)
@given(n=dims(2, 128), e=dims(1, 256), d=dims(1, 48), seed=seeds())
def test_mp_matches_ref(n, e, d, seed):
    rng = np.random.default_rng(seed)
    src, dst, coef, x = _graph(rng, n, e, d)
    got = mp.message_passing(src, dst, coef, x)
    want = ref.message_passing_ref(src, dst, coef, x)
    np.testing.assert_allclose(got, want, **TOL)


@settings(max_examples=15, deadline=None)
@given(n=dims(2, 64), e=dims(8, 128), d=dims(1, 32),
       pad=st.floats(0.0, 0.9), seed=seeds())
def test_mp_padding_is_inert(n, e, d, pad, seed):
    """Zero-coef (padding) edges contribute exactly nothing."""
    rng = np.random.default_rng(seed)
    src, dst, coef, x = _graph(rng, n, e, d, pad_frac=pad)
    n_real = int(np.count_nonzero(np.asarray(coef)))
    # truncate to only the real (nonzero-coef) prefix; result must match
    nz = np.flatnonzero(np.asarray(coef))
    got_padded = mp.message_passing(src, dst, coef, x)
    want_trunc = ref.message_passing_ref(
        jnp.asarray(np.asarray(src)[nz]), jnp.asarray(np.asarray(dst)[nz]),
        jnp.asarray(np.asarray(coef)[nz]), x) if len(nz) else jnp.zeros_like(x)
    np.testing.assert_allclose(got_padded, want_trunc, **TOL)
    assert n_real == len(nz)


def test_mp_parallel_edges_accumulate():
    """Multi-edges between the same pair must sum (multigraph support —
    both BC-Alpha and UCI are multigraphs)."""
    src = jnp.asarray([0, 0, 0], jnp.int32)
    dst = jnp.asarray([1, 1, 1], jnp.int32)
    coef = jnp.asarray([1.0, 2.0, 3.0], jnp.float32)
    x = jnp.asarray([[1.0, 1.0], [0.0, 0.0]], jnp.float32)
    out = np.asarray(mp.message_passing(src, dst, coef, x))
    np.testing.assert_allclose(out[1], [6.0, 6.0], **TOL)
    np.testing.assert_allclose(out[0], [0.0, 0.0], **TOL)


def test_mp_self_loop():
    src = jnp.asarray([0], jnp.int32)
    dst = jnp.asarray([0], jnp.int32)
    coef = jnp.asarray([0.5], jnp.float32)
    x = jnp.asarray([[2.0, 4.0]], jnp.float32)
    out = np.asarray(mp.message_passing(src, dst, coef, x))
    np.testing.assert_allclose(out[0], [1.0, 2.0], **TOL)


def test_mp_isolated_nodes_zero(rng):
    """Nodes with no in-edges end up exactly zero."""
    src = jnp.asarray([0, 1], jnp.int32)
    dst = jnp.asarray([1, 0], jnp.int32)
    coef = jnp.asarray([1.0, 1.0], jnp.float32)
    x = jnp.asarray(rng.normal(size=(5, 4)), jnp.float32)
    out = np.asarray(mp.message_passing(src, dst, coef, x))
    assert (out[2:] == 0).all()


@settings(max_examples=10, deadline=None)
@given(n=dims(2, 32), e=dims(1, 64), d=dims(1, 16), seed=seeds())
def test_gcn_layer_composition(n, e, d, seed):
    """MP ∘ NT composition equals the fused reference layer."""
    rng = np.random.default_rng(seed)
    src, dst, coef, x = _graph(rng, n, e, d)
    w = jnp.asarray(rng.normal(size=(d, d)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    sc = jnp.asarray(rng.normal(size=(n,)) * 0.5, jnp.float32)
    got = mp.gcn_layer(src, dst, coef, sc, x, w, b, relu=True)
    want = ref.gcn_layer_ref(src, dst, coef, sc, x, w, b, relu=True)
    np.testing.assert_allclose(got, want, **TOL)


@settings(max_examples=10, deadline=None)
@given(n=dims(2, 32), e=dims(1, 64), d=dims(1, 16), seed=seeds())
def test_aggregate_selfloop_diagonal(n, e, d, seed):
    """aggregate == MP + diag(selfcoef)·X, and matches an explicit
    edge-list encoding of the self-loops."""
    rng = np.random.default_rng(seed)
    src, dst, coef, x = _graph(rng, n, e, d)
    sc = jnp.asarray(rng.normal(size=(n,)) * 0.5, jnp.float32)
    got = mp.aggregate(src, dst, coef, sc, x)
    # explicit encoding: append n self-loop edges
    src2 = jnp.concatenate([src, jnp.arange(n, dtype=jnp.int32)])
    dst2 = jnp.concatenate([dst, jnp.arange(n, dtype=jnp.int32)])
    coef2 = jnp.concatenate([coef, sc])
    want = ref.message_passing_ref(src2, dst2, coef2, x)
    np.testing.assert_allclose(got, want, **TOL)


@settings(max_examples=15, deadline=None)
@given(n=dims(2, 64), e=dims(1, 128), d=dims(1, 32), seed=seeds())
def test_stream_and_vector_variants_agree(n, e, d, seed):
    """The edge-streaming (hardware-literal) and vectorised MP kernels
    must be numerically equivalent — the §Perf L1 change is allowed to
    alter performance only."""
    rng = np.random.default_rng(seed)
    src, dst, coef, x = _graph(rng, n, e, d)
    got_v = mp.message_passing(src, dst, coef, x)
    got_s = mp.message_passing_stream(src, dst, coef, x)
    np.testing.assert_allclose(got_v, got_s, rtol=1e-5, atol=1e-5)
