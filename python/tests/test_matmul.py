"""NT-PE (tiled matmul) kernel vs pure-jnp oracle.

Hypothesis sweeps shapes (rows padded to multiples of 8, as the AOT
contract guarantees) and data scales; assert_allclose against ref.py.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as mm
from compile.kernels import ref

from .conftest import dims, seeds

TOL = dict(rtol=1e-5, atol=1e-5)


def _mk(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


@settings(max_examples=25, deadline=None)
@given(m=dims(8, 256, multiple_of=8), k=dims(1, 64), n=dims(1, 64), seed=seeds())
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = _mk(rng, m, k), _mk(rng, k, n)
    np.testing.assert_allclose(mm.matmul(x, w), ref.matmul_ref(x, w), **TOL)


@settings(max_examples=20, deadline=None)
@given(m=dims(8, 128, multiple_of=8), k=dims(1, 48), n=dims(1, 48),
       relu=st.booleans(), seed=seeds())
def test_matmul_bias_act_matches_ref(m, k, n, relu, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _mk(rng, m, k), _mk(rng, k, n), _mk(rng, n)
    got = mm.matmul_bias_act(x, w, b, relu=relu)
    want = ref.matmul_bias_act_ref(x, w, b, relu=relu)
    np.testing.assert_allclose(got, want, **TOL)


@settings(max_examples=10, deadline=None)
@given(block_m=st.sampled_from([8, 16, 32, 64]), seed=seeds())
def test_matmul_block_size_invariant(block_m, seed):
    """Result must not depend on the M-tiling choice."""
    rng = np.random.default_rng(seed)
    x, w = _mk(rng, 64, 32), _mk(rng, 32, 32)
    base = mm.matmul(x, w, block_m=64)
    np.testing.assert_allclose(mm.matmul(x, w, block_m=block_m), base, **TOL)


def test_matmul_zero_operand(rng):
    x = jnp.zeros((32, 16), jnp.float32)
    w = _mk(rng, 16, 16)
    np.testing.assert_allclose(mm.matmul(x, w), np.zeros((32, 16)), **TOL)


def test_matmul_identity(rng):
    x = _mk(rng, 32, 32)
    eye = jnp.eye(32, dtype=jnp.float32)
    np.testing.assert_allclose(mm.matmul(x, eye), x, **TOL)


def test_matmul_large_values(rng):
    """fp32 headroom: values near 1e4 should still match within rtol."""
    x, w = _mk(rng, 16, 16, scale=1e4), _mk(rng, 16, 16)
    np.testing.assert_allclose(mm.matmul(x, w), ref.matmul_ref(x, w),
                               rtol=1e-4, atol=1e-1)


def test_relu_clamps_negative(rng):
    x = _mk(rng, 16, 8)
    w = jnp.eye(8, dtype=jnp.float32) * -1.0
    x8 = x[:, :8]
    out = mm.matmul_bias_act(x8, w, jnp.zeros((8,), jnp.float32), relu=True)
    assert (np.asarray(out) >= 0).all()
