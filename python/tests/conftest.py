"""Shared fixtures/strategies for the kernel and model test suites."""

import numpy as np
import pytest
from hypothesis import strategies as st


@pytest.fixture
def rng():
    return np.random.default_rng(0xD64B)


def dims(min_value=1, max_value=64, multiple_of=1):
    """Strategy for a dimension size, optionally rounded to a multiple."""
    base = st.integers(min_value=min_value, max_value=max_value)
    if multiple_of == 1:
        return base
    return base.map(lambda v: max(multiple_of, (v // multiple_of) * multiple_of))


def seeds():
    return st.integers(min_value=0, max_value=2**31 - 1)
