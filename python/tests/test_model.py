"""L2 model steps vs oracle + temporal rollout + padding invariance."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile import model as M
from compile.kernels import gru, ref

from .conftest import dims, seeds

TOL = dict(rtol=1e-4, atol=1e-4)


def _inputs(rng, n, e, d):
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    coef = jnp.asarray(rng.normal(size=e) * 0.2, jnp.float32)
    sc = jnp.asarray(rng.normal(size=n) * 0.5, jnp.float32)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    return src, dst, coef, sc, x


def _gru_params(rng, rows, cols):
    p = {}
    for k in gru.gru_param_keys():
        shape = (rows, cols) if k.startswith("b") else (rows, rows)
        p[k] = jnp.asarray(rng.normal(size=shape) * 0.1, jnp.float32)
    return p


@settings(max_examples=8, deadline=None)
@given(n=dims(8, 64, multiple_of=8), e=dims(4, 128), d=dims(4, 24), seed=seeds())
def test_evolvegcn_step_matches_ref(n, e, d, seed):
    rng = np.random.default_rng(seed)
    src, dst, coef, sc, x = _inputs(rng, n, e, d)
    w1 = jnp.asarray(rng.normal(size=(d, d)) * 0.3, jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(d, d)) * 0.3, jnp.float32)
    g1, g2 = _gru_params(rng, d, d), _gru_params(rng, d, d)
    flat = [g1[k] for k in gru.gru_param_keys()] + \
           [g2[k] for k in gru.gru_param_keys()]
    got = M.evolvegcn_step(src, dst, coef, sc, x, w1, w2, *flat)
    want = ref.evolvegcn_step_ref(src, dst, coef, sc, x, w1, w2, g1, g2)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, **TOL)


@settings(max_examples=8, deadline=None)
@given(n=dims(8, 64, multiple_of=8), e=dims(4, 128), d=dims(4, 24), seed=seeds())
def test_gcrn_step_matches_ref(n, e, d, seed):
    rng = np.random.default_rng(seed)
    src, dst, coef, sc, x = _inputs(rng, n, e, d)
    h = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    wx = jnp.asarray(rng.normal(size=(d, 4 * d)) * 0.3, jnp.float32)
    wh = jnp.asarray(rng.normal(size=(d, 4 * d)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.normal(size=(4 * d,)), jnp.float32)
    got = M.gcrn_m2_step(src, dst, coef, sc, x, h, c, wx, wh, b)
    want = ref.gcrn_m2_step_ref(src, dst, coef, sc, x, h, c, wx, wh, b)
    for a, bv in zip(got, want):
        np.testing.assert_allclose(a, bv, **TOL)


def test_evolvegcn_weights_independent_of_graph():
    """The evolved weights must not depend on the snapshot — this is the
    independence DGNN-Booster V1 exploits to overlap RNN(t+1) with MP(t)."""
    rng = np.random.default_rng(7)
    d = 8
    w1 = jnp.asarray(rng.normal(size=(d, d)) * 0.3, jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(d, d)) * 0.3, jnp.float32)
    g1, g2 = _gru_params(rng, d, d), _gru_params(rng, d, d)
    flat = [g1[k] for k in gru.gru_param_keys()] + \
           [g2[k] for k in gru.gru_param_keys()]
    outs = []
    for seed in (1, 2):
        r2 = np.random.default_rng(seed)
        src, dst, coef, sc, x = _inputs(r2, 16, 32, d)
        _, w1n, w2n = M.evolvegcn_step(src, dst, coef, sc, x, w1, w2, *flat)
        outs.append((np.asarray(w1n), np.asarray(w2n)))
    np.testing.assert_array_equal(outs[0][0], outs[1][0])
    np.testing.assert_array_equal(outs[0][1], outs[1][1])


def test_gcrn_rollout_stable():
    """Multi-snapshot rollout: hidden state stays bounded (|H| <= 1)."""
    rng = np.random.default_rng(3)
    n, e, d = 32, 64, 8
    h = jnp.zeros((n, d), jnp.float32)
    c = jnp.zeros((n, d), jnp.float32)
    wx = jnp.asarray(rng.normal(size=(d, 4 * d)) * 0.3, jnp.float32)
    wh = jnp.asarray(rng.normal(size=(d, 4 * d)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.normal(size=(4 * d,)), jnp.float32)
    for t in range(10):
        src, dst, coef, sc, x = _inputs(np.random.default_rng(100 + t), n, e, d)
        h, c = M.gcrn_m2_step(src, dst, coef, sc, x, h, c, wx, wh, b)
    assert (np.abs(np.asarray(h)) <= 1.0 + 1e-6).all()
    assert np.isfinite(np.asarray(c)).all()


def test_padding_invariance_full_contract():
    """A snapshot padded to MAX shapes gives identical results on real
    node rows as the unpadded computation — the core AOT contract."""
    rng = np.random.default_rng(11)
    d = 8
    n_real, e_real = 24, 40
    n_pad, e_pad = 32, 64
    src_r, dst_r, coef_r, sc_r, x_r = _inputs(rng, n_real, e_real, d)
    h = jnp.asarray(rng.normal(size=(n_real, d)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(n_real, d)), jnp.float32)
    wx = jnp.asarray(rng.normal(size=(d, 4 * d)) * 0.3, jnp.float32)
    wh = jnp.asarray(rng.normal(size=(d, 4 * d)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.normal(size=(4 * d,)), jnp.float32)

    def pad1(a, n, fill=0):
        out = np.full((n,) + a.shape[1:], fill, a.dtype)
        out[: a.shape[0]] = np.asarray(a)
        return jnp.asarray(out)

    got_h, got_c = M.gcrn_m2_step(
        pad1(src_r, e_pad), pad1(dst_r, e_pad), pad1(coef_r, e_pad),
        pad1(sc_r, n_pad), pad1(x_r, n_pad), pad1(h, n_pad), pad1(c, n_pad),
        wx, wh, b)
    want_h, want_c = M.gcrn_m2_step(src_r, dst_r, coef_r, sc_r, x_r, h, c,
                                    wx, wh, b)
    np.testing.assert_allclose(np.asarray(got_h)[:n_real], want_h, **TOL)
    np.testing.assert_allclose(np.asarray(got_c)[:n_real], want_c, **TOL)


@settings(max_examples=6, deadline=None)
@given(n=dims(8, 48, multiple_of=8), e=dims(4, 96), d=dims(4, 16), seed=seeds())
def test_gcrn_m1_step_matches_ref(n, e, d, seed):
    rng = np.random.default_rng(seed)
    src, dst, coef, sc, x = _inputs(rng, n, e, d)
    h = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(d, d)) * 0.3, jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(d, d)) * 0.3, jnp.float32)
    wx = jnp.asarray(rng.normal(size=(d, 4 * d)) * 0.3, jnp.float32)
    wh = jnp.asarray(rng.normal(size=(d, 4 * d)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.normal(size=(4 * d,)), jnp.float32)
    got = M.gcrn_m1_step(src, dst, coef, sc, x, h, c, w1, w2, wx, wh, b)
    want = ref.gcrn_m1_step_ref(src, dst, coef, sc, x, h, c, w1, w2, wx, wh, b)
    for a, bv in zip(got, want):
        np.testing.assert_allclose(a, bv, **TOL)


def test_gcrn_m1_gnn_independent_of_rnn_state():
    """Stacked-DGNN property (Table I): the GNN encoding is independent
    of H/C — the independence both Booster designs exploit."""
    rng = np.random.default_rng(21)
    d = 8
    src, dst, coef, sc, x = _inputs(rng, 16, 32, d)
    w1 = jnp.asarray(rng.normal(size=(d, d)) * 0.3, jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(d, d)) * 0.3, jnp.float32)
    wx = jnp.asarray(rng.normal(size=(d, 4 * d)) * 0.3, jnp.float32)
    wh = jnp.zeros((d, 4 * d), jnp.float32)  # decouple H from the gates
    b = jnp.asarray(rng.normal(size=(4 * d,)), jnp.float32)
    outs = []
    for hseed in (1, 2):
        r = np.random.default_rng(hseed)
        h = jnp.asarray(r.normal(size=(16, d)), jnp.float32)
        c = jnp.zeros((16, d), jnp.float32)
        hn, _ = M.gcrn_m1_step(src, dst, coef, sc, x, h, c, w1, w2, wx, wh, b)
        outs.append(np.asarray(hn))
    np.testing.assert_allclose(outs[0], outs[1], **TOL)
